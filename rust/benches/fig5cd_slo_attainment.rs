//! Fig. 5c/5d — SLO attainment vs. server RPS (Alpaca / Mixed),
//! BucketServe vs. DistServe.
//!
//! Paper claim: at the 80% attainment level BucketServe sustains ≈ 1.37×
//! (Alpaca) and ≈ 1.93× (Mixed) the server RPS of DistServe. We sweep the
//! offered load on paired traces, print the attainment curves, and
//! interpolate each system's RPS at 80%.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn rps_at_80(curve: &[(f64, f64)]) -> f64 {
    // Highest load whose attainment ≥ 0.8, with linear interpolation into
    // the first point below.
    let mut best = 0.0;
    for w in curve.windows(2) {
        let (r0, a0) = w[0];
        let (r1, a1) = w[1];
        if a0 >= 0.8 {
            best = r0;
            if a1 < 0.8 && a0 > a1 {
                best = r0 + (r1 - r0) * (a0 - 0.8) / (a0 - a1);
            }
        }
    }
    if let Some(&(r, a)) = curve.last() {
        if a >= 0.8 {
            best = r;
        }
    }
    best
}

fn main() {
    let n = 300;
    let loads = [1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0];

    for (fig, dataset) in [("5c", Dataset::Alpaca), ("5d", Dataset::Mixed)] {
        let mut cfg = SystemConfig::default();
        if dataset == Dataset::Mixed {
            // Long-prompt prefill alone is ~0.7 s on this testbed; the
            // paper's Mixed SLO must be achievable, so scale TTFT to the
            // workload (DistServe does the same per-workload SLO scaling).
            cfg.slo.ttft_us = 1_500_000;
            cfg.slo.tbt_us = 150_000;
        }
        println!("\nFig. {fig} — SLO attainment vs server RPS ({})", dataset.name());
        let mut t = Table::new(&[
            "client RPS", "BS server RPS", "BS SLO", "DS server RPS", "DS SLO",
        ]);
        let mut curve_b = Vec::new();
        let mut curve_d = Vec::new();
        for &rps in &loads {
            let trace = Trace::generate(
                dataset, n, rps, RequestClass::Online, cfg.model.max_seq, cfg.seed,
            );
            let rb = System::BucketServe.run_sim(&cfg, &trace);
            let rd = System::DistServe.run_sim(&cfg, &trace);
            let ab = rb.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
            let ad = rd.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
            curve_b.push((rb.server_rps(), ab));
            curve_d.push((rd.server_rps(), ad));
            t.row(vec![
                f2(rps),
                f2(rb.server_rps()),
                f2(ab),
                f2(rd.server_rps()),
                f2(ad),
            ]);
        }
        t.print(&format!("attainment curves ({})", dataset.name()));
        let cb = rps_at_80(&curve_b);
        let cd = rps_at_80(&curve_d);
        let paper = if dataset == Dataset::Alpaca { 1.37 } else { 1.93 };
        println!(
            "server RPS at 80% SLO: BucketServe {:.2}, DistServe {:.2} → ratio {:.2}× (paper {paper}×)",
            cb,
            cd,
            if cd > 0.0 { cb / cd } else { f64::INFINITY }
        );
    }
}
