//! Fig. 5b — Average GPU utilization vs. request count, 3 systems.
//!
//! Paper claim: BucketServe's dynamic batching lifts average GPU
//! utilization to ≈ 81.66%, the highest of the three systems, with the gap
//! widening under more requests.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    let cfg = SystemConfig::default();
    println!("Fig. 5b — average GPU utilization, Mixed offline workload\n");

    let mut t = Table::new(&["requests", "BucketServe", "DistServe", "UELLM"]);
    let mut peak = 0.0f64;
    for &n in &[64usize, 128, 256, 512] {
        let trace = Trace::batch(
            Dataset::Mixed, n, RequestClass::Offline, cfg.model.max_seq, cfg.seed,
        );
        let ub = System::BucketServe.run_sim(&cfg, &trace).gpu_util();
        let ud = System::DistServe.run_sim(&cfg, &trace).gpu_util();
        let uu = System::Uellm.run_sim(&cfg, &trace).gpu_util();
        peak = peak.max(ub);
        t.row(vec![n.to_string(), f2(ub), f2(ud), f2(uu)]);
    }
    t.print("average GPU utilization");
    println!(
        "\nBucketServe peak util {:.1}% (paper: 81.66%); ordering BucketServe > DistServe > UELLM expected.",
        peak * 100.0
    );
}
