//! Fig. 5e/5f — Server RPS vs. client RPS (Alpaca / Mixed), 3 systems.
//!
//! Paper claims: BucketServe tracks the ideal y = x line the longest; on
//! Alpaca it reaches ≈ 1.975× UELLM's server RPS, and on Mixed ≈ 1.4× /
//! 3.47× DistServe / UELLM. We replay paired traces at increasing offered
//! load and report each system's sustained completion rate.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    let cfg = SystemConfig::default();
    let n = 300;
    let loads = [2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0];

    for (fig, dataset, paper_note) in [
        ("5e", Dataset::Alpaca, "paper: BucketServe ≈ 1.975× UELLM"),
        ("5f", Dataset::Mixed, "paper: ≈ 1.4× DistServe, ≈ 3.47× UELLM"),
    ] {
        println!("\nFig. {fig} — server RPS vs client RPS ({})", dataset.name());
        let mut t = Table::new(&[
            "client RPS", "ideal", "BucketServe", "DistServe", "UELLM",
        ]);
        let mut sat = [0.0f64; 3];
        for &rps in &loads {
            let trace = Trace::generate(
                dataset, n, rps, RequestClass::Online, cfg.model.max_seq, cfg.seed,
            );
            let mut row = vec![f2(rps), f2(rps)];
            for (i, system) in System::ALL.iter().enumerate() {
                let srv = system.run_sim(&cfg, &trace).server_rps().min(rps);
                sat[i] = sat[i].max(srv);
                row.push(f2(srv));
            }
            t.row(row);
        }
        t.print(&format!("throughput tracking ({})", dataset.name()));
        println!(
            "max sustained server RPS: BucketServe {:.2}, DistServe {:.2}, UELLM {:.2}",
            sat[0], sat[1], sat[2]
        );
        println!(
            "ratios: {:.2}× DistServe, {:.2}× UELLM   ({paper_note})",
            sat[0] / sat[1].max(1e-9),
            sat[0] / sat[2].max(1e-9)
        );
    }
}
