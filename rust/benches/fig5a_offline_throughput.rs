//! Fig. 5a — Offline throughput (tokens/s) vs. request count, 3 systems.
//!
//! Paper claims at high load: BucketServe ≈ 3.58× UELLM and ≈ 1.31×
//! DistServe. We sweep the offered batch size on the simulated 4×A100
//! testbed (Llama2-13B, Mixed workload) and print tokens/s per system plus
//! the achieved ratios.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    let cfg = SystemConfig::default();
    println!("Fig. 5a — offline throughput, Mixed workload, 2P+2D A100 node\n");

    let mut t = Table::new(&[
        "requests", "BucketServe tok/s", "DistServe tok/s", "UELLM tok/s",
        "vs DS", "vs UELLM",
    ]);
    let mut last = (0.0, 0.0);
    for &n in &[64usize, 128, 256, 512] {
        let trace = Trace::batch(
            Dataset::Mixed, n, RequestClass::Offline, cfg.model.max_seq, cfg.seed,
        );
        let tb = System::BucketServe.run_sim(&cfg, &trace).throughput_tps();
        let td = System::DistServe.run_sim(&cfg, &trace).throughput_tps();
        let tu = System::Uellm.run_sim(&cfg, &trace).throughput_tps();
        last = (tb / td, tb / tu);
        t.row(vec![
            n.to_string(),
            f1(tb),
            f1(td),
            f1(tu),
            f2(tb / td),
            f2(tb / tu),
        ]);
    }
    t.print("offline throughput sweep");
    println!(
        "\nhigh-load ratios: {:.2}× DistServe (paper 1.31×), {:.2}× UELLM (paper 3.58×)",
        last.0, last.1
    );
}
