//! Fig. 2 — Distribution of LLM requests (Alpaca / LongBench / Mixed).
//!
//! Regenerates the paper's workload-characterization figure from our
//! synthetic samplers: per-dataset input-length histograms plus the
//! summary statistics the paper quotes (Alpaca mean ≈ 83 tokens;
//! LongBench long-tail with median 41,417 before truncation).

use bucketserve::util::bench::{f0, f1, Table};
use bucketserve::util::rng::Pcg;
use bucketserve::util::stats::{Histogram, Samples};
use bucketserve::workload::{Dataset, LengthSampler};

fn main() {
    let n = 50_000;
    println!("Fig. 2 — request length distributions ({n} samples/dataset)\n");

    // 2a: Alpaca with the paper-scale context (4096).
    characterize("Fig 2a — Alpaca", Dataset::Alpaca, 4096, n,
                 &[0.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0]);
    // 2b: LongBench, shown untruncated to expose the long tail the paper
    // reports, then truncated to the serving context as the system sees it.
    characterize("Fig 2b — LongBench (raw tail)", Dataset::LongBench, 1_000_000, n,
                 &[0.0, 4096.0, 16384.0, 41417.0, 100_000.0, 250_000.0]);
    characterize("Fig 2b' — LongBench (truncated to 4096 ctx)",
                 Dataset::LongBench, 4096, n,
                 &[0.0, 1024.0, 2048.0, 3072.0, 4095.0]);
    characterize("Mixed (70/30 short/long)", Dataset::Mixed, 4096, n,
                 &[0.0, 64.0, 256.0, 1024.0, 2048.0, 4095.0]);

    println!("\npaper anchors: Alpaca mean 83 tokens; LongBench median 41,417 (pre-truncation).");
}

fn characterize(title: &str, dataset: Dataset, max_seq: u32, n: usize, edges: &[f64]) {
    let sampler = dataset.sampler(max_seq);
    let mut rng = Pcg::seeded(42);
    let mut hist = Histogram::new(edges.to_vec());
    let mut inputs = Samples::new();
    let mut outputs = Samples::new();
    for _ in 0..n {
        let (i, o) = sampler.sample(&mut rng);
        hist.push(i as f64);
        inputs.push(i as f64);
        outputs.push(o as f64);
    }
    let mut t = Table::new(&["input-length bin", "count", "fraction"]);
    for (label, count, frac) in hist.rows() {
        t.row(vec![label, count.to_string(), format!("{frac:.3}")]);
    }
    t.print(title);
    println!(
        "input  mean {} | median {} | p95 {} | max {}",
        f1(inputs.mean()),
        f0(inputs.median()),
        f0(inputs.percentile(95.0)),
        f0(inputs.max())
    );
    println!(
        "output mean {} | median {}",
        f1(outputs.mean()),
        f0(outputs.median())
    );
}
