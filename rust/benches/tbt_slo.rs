//! Ablation: TBT-aware decode admission (deferral + TBT eviction) vs the
//! admission-free scheduler, swept over offline decode oversubscription.
//!
//! The scenario is the one TTFT-side machinery cannot fix: once an
//! offline LongBench wave is *decoding*, its KV sits on the instance and
//! every continuous-batching iteration streams it — the online sequences
//! sharing the batch then receive tokens at the stretched iteration
//! cadence, blowing their time-between-tokens budget with nobody
//! watching. Priority reordering and preemption act on *queued* work;
//! only the admission layer acts per iteration on *resident* work.
//!
//! Timing: the per-token budget is set to 30 ms — above the weight-read
//! floor of a lone batch's iteration (~24 ms on the modeled A100 fleet
//! serving 13B) but below a KV-saturated instance's (~35 ms at the ~14k
//! token budget) — so offline oversubscription is a real TBT hazard the
//! eviction trigger can actually cure by shedding context. One prefill +
//! one decode instance keeps the oversubscription on a single, readable
//! instance.
//!
//! Sweep: offline backlog size at fixed online load, admission off/on on
//! the *same* trace (paired). Expected shape: online TBT attainment (and
//! the p99 inter-token gap) degrades with backlog when admission is off
//! and is held near the budget when on, paid for in deferrals, TBT
//! evictions (recompute), and offline throughput. Each run also emits
//! its Summary JSON on stdout (one line per run); the TBT block appears
//! only in the admission-enabled rows.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::metrics::Summary;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    println!("tbt_slo — TBT-aware admission vs offline decode oversubscription\n");
    let mut base = SystemConfig::default();
    base.fleet.n_prefill = 1;
    base.fleet.n_decode = 1;
    base.slo.tbt_us = 30_000;
    let mut t = Table::new(&[
        "offline n", "admission", "online TBT attain", "online p50 gap ms",
        "online p99 gap ms", "offline TBT attain", "deferrals", "tbt evict",
        "online TTFT ms", "tok/s",
    ]);
    for &n_offline in &[8usize, 16, 32] {
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 120, 8.0, Dataset::LongBench, n_offline,
            base.model.max_seq, base.seed,
        );
        for (label, enabled) in [("off", false), ("on", true)] {
            let mut cfg = base.clone();
            cfg.admission.enabled = enabled;
            let r = System::BucketServe.run_sim(&cfg, &trace);
            let s = Summary::from_report(
                &format!("BucketServe/admission-{label}/off{n_offline}"),
                &r,
                &cfg.slo,
            );
            println!("{}", s.to_json());
            t.row(vec![
                n_offline.to_string(),
                label.to_string(),
                f2(r.tbt_attainment_class(RequestClass::Online)),
                f1(r.tbt_gap_percentile_us(RequestClass::Online, 50.0) / 1e3),
                f1(r.tbt_gap_percentile_us(RequestClass::Online, 99.0) / 1e3),
                f2(r.tbt_attainment_class(RequestClass::Offline)),
                r.admission_deferrals.to_string(),
                r.tbt_evictions.to_string(),
                f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                f1(r.throughput_tps()),
            ]);
        }
    }
    t.print(
        "ablation: TBT admission on/off \
         (offline LongBench backlog @ t=0 + 8 rps online Alpaca, 30 ms TBT)",
    );
}
