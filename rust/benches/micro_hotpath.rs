//! Hot-path micro-benchmarks (the §Perf iteration loop's instrument).
//!
//! Times the coordinator's per-request-path operations: bucket assignment
//! (binary vs. linear), AdjustBuckets, batch formation, the Eq. 1–6
//! memory model, the cost model, the executor's boundary and plan/commit
//! sync points at 8 shards (pool vs inline), and JSON parsing (gateway
//! protocol).

use bucketserve::config::{Policy, SystemConfig};
use bucketserve::coordinator::batcher::{DynamicBatcher, KvMemoryModel};
use bucketserve::coordinator::bucket::{BucketManager, QueuedReq};
use bucketserve::coordinator::prefix::PrefixStamp;
use bucketserve::cluster::gpu::CostModel;
use bucketserve::coordinator::PriorityScorer;
use bucketserve::util::bench::time_it;
use bucketserve::util::json::Json;
use bucketserve::util::rng::Pcg;
use bucketserve::workload::RequestClass;

fn filled_manager(n: usize, buckets: bool) -> BucketManager {
    let mut mgr = BucketManager::new(4096, 0.5, 16);
    let mut rng = Pcg::seeded(3);
    for i in 0..n {
        mgr.assign(QueuedReq {
            id: i as u64,
            len: rng.range(1, 4000) as u32,
            output_len: rng.range(1, 400) as u32,
            arrival: i as u64,
            class: RequestClass::Online,
            tbt_us: 0,
            prefix: PrefixStamp::default(),
        });
    }
    if buckets {
        for _ in 0..6 {
            mgr.adjust(16);
        }
    }
    mgr
}

fn main() {
    println!("micro_hotpath — coordinator hot-path timings\n");
    let cfg = SystemConfig::default();

    // Bucket assignment at realistic bucket counts.
    for &(label, linear) in &[("binary", false), ("linear", true)] {
        let mut mgr = filled_manager(256, true);
        mgr.linear_scan = linear;
        let mut rng = Pcg::seeded(9);
        let mut id = 10_000u64;
        let k = mgr.n_buckets();
        time_it(&format!("assign/{label} (k={k})"), || {
            let len = rng.range(1, 4000) as u32;
            id += 1;
            mgr.assign(QueuedReq {
                id,
                len,
                output_len: 10,
                arrival: id,
                class: RequestClass::Online,
                tbt_us: 0,
                prefix: PrefixStamp::default(),
            });
            // Bound queue growth.
            if mgr.total() > 4096 {
                for b in mgr.buckets_mut() {
                    b.requests.clear();
                }
            }
        })
        .print();
    }

    // AdjustBuckets on a loaded manager.
    {
        let mgr0 = filled_manager(512, false);
        time_it("adjust_buckets (512 queued)", || {
            let mut m = mgr0.clone();
            m.adjust(16);
            m.n_buckets()
        })
        .print();
    }

    // Batch formation.
    {
        let mgr0 = filled_manager(512, true);
        let batcher = DynamicBatcher::new(cfg.model.clone(), &cfg.scheduler);
        time_it("form_batch (512 queued)", || {
            let mut m = mgr0.clone();
            batcher.form_batch(&mut m, 0, 8192)
        })
        .print();
        // Isolate the clone cost to subtract mentally.
        time_it("  (manager clone baseline)", || mgr0.clone().total()).print();
    }

    // Eq. 1–6 memory model.
    {
        let mm = KvMemoryModel::new(cfg.model.clone(), 0.9);
        let lens: Vec<u32> = (0..64).map(|i| 100 + i * 13).collect();
        time_it("kv memory model n_max (64 lens)", || {
            mm.n_max(lens.iter().copied(), 1_000_000)
        })
        .print();
    }

    // Cost model (the simulator's inner loop).
    {
        let cm = CostModel::new(cfg.model.clone(), cfg.gpu.clone(), 1);
        time_it("cost: prefill_time", || cm.prefill_time(8, 1024)).print();
        time_it("cost: decode_step_time", || cm.decode_step_time(16, 16 * 512)).print();
    }

    // Intra-bucket policy sort (the per-plan cost at depth).
    {
        let mut sched = cfg.scheduler.clone();
        sched.policy = Policy::Sjf;
        let batcher = DynamicBatcher::new(cfg.model.clone(), &sched);
        let mgr0 = filled_manager(1024, false);
        time_it("form_batch SJF (1024 queued, 1 bucket)", || {
            let mut m = mgr0.clone();
            batcher.form_batch(&mut m, 0, 16_384)
        })
        .print();
    }

    // Priority drain at depth: the intra-bucket sort runs on a
    // precomputed DrainKey per request (sort_by_cached_key) instead of
    // re-deriving float scores inside the comparator.
    {
        let batcher = DynamicBatcher::new(cfg.model.clone(), &cfg.scheduler)
            .with_priority(PriorityScorer::new(
                cfg.priority.clone(),
                cfg.slo.clone(),
            ));
        let mut mgr0 = BucketManager::new(4096, 0.5, 16);
        let mut rng = Pcg::seeded(7);
        for i in 0..1024u64 {
            mgr0.assign(QueuedReq {
                id: i,
                len: rng.range(1, 4000) as u32,
                output_len: rng.range(1, 400) as u32,
                arrival: i * 1000,
                class: if i % 3 == 0 {
                    RequestClass::Online
                } else {
                    RequestClass::Offline
                },
                tbt_us: 0,
                prefix: PrefixStamp::default(),
            });
        }
        time_it("form_batch priority (1024 queued, cached key)", || {
            let mut m = mgr0.clone();
            batcher.form_batch(&mut m, 5_000_000, 16_384)
        })
        .print();
    }

    // Preemption checkpoint/restore hot path: victim selection over a
    // loaded decode instance, then the full evict cycle (selection +
    // checkpoint + requeue into a bucket manager). Runs inside the
    // scheduler's event loop when enabled, so it must stay well under
    // the per-event budget.
    {
        use bucketserve::coordinator::fleet::DecodeSeqState;
        use bucketserve::coordinator::preempt::PreemptionEngine;
        let mut spec = cfg.preempt.clone();
        spec.enabled = true;
        spec.max_evictions = 8;
        let engine = PreemptionEngine::new(
            spec.clone(),
            cfg.priority.clone(),
            cfg.slo.clone(),
        );
        let mut rng = Pcg::seeded(11);
        let active: Vec<DecodeSeqState> = (0..64u64)
            .map(|i| DecodeSeqState {
                id: i,
                class: RequestClass::Offline,
                arrival: i * 1000,
                input_len: rng.range(100, 3000) as u32,
                padded_len: 4096,
                output_len: rng.range(50, 400) as u32,
                generated: rng.range(1, 40) as u32,
                first_token: i * 1000 + 500,
                ready_at: 0,
                tbt_us: 0,
                last_token_at: 0,
                prefix: PrefixStamp::default(),
            })
            .collect();
        time_it("preempt: pick_decode_victims (64 active)", || {
            engine.pick_decode_victims(&active, 6_000, 10_000_000)
        })
        .print();
        // Engine and empty manager hoisted out of the closure: the
        // measured body is only what the scheduler's event loop runs —
        // victim selection, checkpoint, requeue-assign, and the restore
        // lookup the recompute prefill pays later. take_restore also
        // keeps the checkpoint map bounded across iterations.
        let mut eng = PreemptionEngine::new(
            spec.clone(),
            cfg.priority.clone(),
            cfg.slo.clone(),
        );
        let mgr0 = BucketManager::new(4096, 0.5, 16);
        time_it("preempt: evict+restore cycle (8 victims)", || {
            let mut mgr = mgr0.clone();
            let victims = eng.pick_decode_victims(&active, 6_000, 10_000_000);
            for id in &victims {
                let s = active.iter().find(|s| s.id == *id).unwrap();
                let entry = eng.checkpoint_seq(s);
                mgr.assign(entry);
            }
            for id in &victims {
                eng.take_restore(*id);
            }
            victims.len()
        })
        .print();
        // Isolate the (empty) manager clone cost to subtract mentally.
        time_it("  (manager clone baseline)", || mgr0.clone().total()).print();
    }

    // Chunked-prefill park/resume hot path: the globally-oldest resume
    // selection (min head `started_at` across shard FIFOs) plus the
    // O(1) VecDeque pop — previously a Vec::remove(0) front shift —
    // against a deep parked backlog. Runs once per dispatch round when
    // chunking is on, so it must stay flat in backlog depth.
    {
        use bucketserve::cluster::PrefillBatch;
        use bucketserve::config::ShardingSpec;
        use bucketserve::coordinator::batcher::FormedBatch;
        use bucketserve::coordinator::fleet::ParkedPrefill;
        use bucketserve::coordinator::scheduler::BucketPlanner;
        use bucketserve::coordinator::shard::ShardSet;
        use bucketserve::coordinator::PrefillPlanner;

        const SHARDS: usize = 8;
        let spec =
            ShardingSpec { shards: SHARDS as u32, ..Default::default() };
        let mut set = ShardSet::new(&spec, SHARDS, || {
            Box::new(BucketPlanner::new(&cfg)) as Box<dyn PrefillPlanner>
        });
        let parked = |t: u64| ParkedPrefill {
            formed: FormedBatch {
                batch: PrefillBatch { items: vec![], padded_len: 1 },
                reqs: vec![],
                bucket_up: 1,
            },
            target_decode: 0,
            started_at: t,
            cursor: 0,
            width: 1,
            reserved_so_far: 0,
            exec_us: 0,
        };
        for si in 0..SHARDS {
            for i in 0..64u64 {
                let t = i * SHARDS as u64 + si as u64;
                set.get_mut(si).parked.push_back(parked(t));
            }
        }
        let mut next = (SHARDS * 64) as u64;
        time_it("park/resume: oldest scan + pop_front (8×64 parked)", || {
            let si = set.oldest_parked_shard().unwrap();
            let p = set.get_mut(si).parked.pop_front().unwrap();
            // Re-park at the tail to keep the backlog depth steady.
            set.get_mut(si).parked.push_back(parked(next));
            next += 1;
            p.started_at
        })
        .print();
    }

    // Executor sync points at 8 shards: one decode-iteration boundary
    // fan-out and one plan/commit speculation round, pool vs inline.
    // Job capture (buffer moves, planner clone_box snapshots) runs on
    // the merge loop in both modes, so both closures pay it identically;
    // the pool rows measure what fanning the pure computation out to
    // per-shard workers costs/saves against running it inline.
    {
        use bucketserve::coordinator::executor::{
            self, BoundaryJob, ExecutorPool, PlanJob, SyncKey,
        };
        use bucketserve::coordinator::fleet::DecodeSeqState;
        use bucketserve::coordinator::scheduler::BucketPlanner;
        use bucketserve::coordinator::PrefillPlanner;
        use bucketserve::workload::Request;

        const SHARDS: usize = 8;
        let pool = ExecutorPool::new(SHARDS);

        // Boundary sync point: 8 instances × 64 active sequences.
        let mut rng = Pcg::seeded(13);
        let actives: Vec<Vec<DecodeSeqState>> = (0..SHARDS)
            .map(|di| {
                (0..64u64)
                    .map(|i| DecodeSeqState {
                        id: di as u64 * 100 + i,
                        class: RequestClass::Online,
                        arrival: i,
                        input_len: rng.range(100, 3000) as u32,
                        padded_len: 4096,
                        output_len: rng.range(50, 400) as u32,
                        generated: rng.range(1, 40) as u32,
                        first_token: 500,
                        ready_at: 0,
                        tbt_us: 0,
                        last_token_at: 900,
                        prefix: PrefixStamp::default(),
                    })
                    .collect()
            })
            .collect();
        let bjobs = |src: &[Vec<DecodeSeqState>]| -> Vec<BoundaryJob> {
            src.iter()
                .enumerate()
                .map(|(di, a)| BoundaryJob {
                    key: SyncKey { at: 1_000, event: di as u64, shard: di },
                    di,
                    iter_end: 1_000,
                    active: a.clone(),
                    gaps: Vec::new(),
                    done: Vec::new(),
                    stall_us: 0,
                })
                .collect()
        };
        time_it("executor: 8-boundary sync point (pool)", || {
            pool.process(bjobs(&actives)).len()
        })
        .print();
        time_it("executor: 8-boundary sync point (inline)", || {
            bjobs(&actives)
                .into_iter()
                .map(executor::boundary_outcome)
                .count()
        })
        .print();

        // Plan/commit sync point: 8 shards × 256 queued requests each.
        let mut rng = Pcg::seeded(17);
        let planners: Vec<BucketPlanner> = (0..SHARDS)
            .map(|si| {
                let mut p = BucketPlanner::new(&cfg);
                for i in 0..256u64 {
                    let r = Request::new(
                        si as u64 * 1_000 + i,
                        if i % 3 == 0 {
                            RequestClass::Online
                        } else {
                            RequestClass::Offline
                        },
                        rng.range(1, 4000) as u32,
                        rng.range(1, 400) as u32,
                        i,
                    );
                    p.admit(&r, i);
                }
                p
            })
            .collect();
        let pjobs = |src: &[BucketPlanner]| -> Vec<PlanJob> {
            src.iter()
                .enumerate()
                .map(|(si, p)| PlanJob {
                    key: SyncKey { at: 1_000, event: si as u64, shard: si },
                    now: 1_000,
                    headroom: 100_000,
                    snapshot: p.clone_box(),
                })
                .collect()
        };
        time_it("executor: 8-plan sync point (pool)", || {
            pool.plan(pjobs(&planners)).len()
        })
        .print();
        time_it("executor: 8-plan sync point (inline)", || {
            pjobs(&planners)
                .into_iter()
                .map(executor::speculate_plan)
                .count()
        })
        .print();
        // Isolate the snapshot (capture) cost to subtract mentally.
        time_it("  (snapshot baseline: 8 clone_box)", || {
            pjobs(&planners).len()
        })
        .print();
    }

    // Gateway JSON parse (TCP protocol hot path).
    {
        let line = r#"{"op":"req","input_len":182,"output_len":96,"class":"online","arrival":123456}"#;
        time_it("json parse gateway line", || Json::parse(line).unwrap()).print();
    }
}
