//! Planner-family sweep: bucket (the paper's padding-greedy planner),
//! FCFS (the DistServe-style baseline), and deadline-lookahead, crossed
//! over TTFT-deadline tightness × online load.
//!
//! The scenario is the planner's hardest regime: an offline LongBench
//! backlog at t=0 competes with an online Alpaca stream for the prefill
//! instances. Each family resolves the contention differently:
//!
//!  * bucket forms the best-packed batch from its length buckets —
//!    padding-efficient, but deadline-blind within a drain round;
//!  * fcfs serves strict arrival order — fair, but lets a long offline
//!    head pad and delay the online tail behind it;
//!  * lookahead sorts by effective deadline (online: arrival + TTFT SLO;
//!    offline: arrival + aging horizon), forms batches backwards from
//!    the earliest deadline over a bounded window, and *holds* an
//!    unsaturated batch while every member's latest feasible start is
//!    still beyond the commit margin — trading idle slack for fuller,
//!    better-aimed batches.
//!
//! At tight deadlines under overload, lookahead should convert the same
//! GPU time into higher online TTFT attainment at equal-or-better
//! throughput; at loose deadlines all three should converge (the hold
//! gate barely fires and deadline order degenerates toward arrival
//! order). The `pad eff` column (useful / busy prefill time) shows what
//! the deadline-aimed formation costs in padding versus bucket's
//! length-grouped batches. Each run also emits its Summary JSON on
//! stdout (one line per run) for trajectory tooling.

use bucketserve::baselines::System;
use bucketserve::config::{PlannerFamily, SystemConfig};
use bucketserve::metrics::Summary;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    println!("lookahead_slo — planner families × deadline tightness × load\n");
    let mut t = Table::new(&[
        "ttft SLO", "rps", "planner", "online SLO", "online TTFT ms",
        "offline SLO", "tok/s", "pad eff",
    ]);
    for &(ttft_us, tag) in &[(400_000u64, "tight"), (2_000_000, "loose")] {
        for &rps in &[8.0, 20.0] {
            let mut base = SystemConfig::default();
            base.slo.ttft_us = ttft_us;
            let trace = Trace::mixed_classes(
                Dataset::Alpaca, 120, rps, Dataset::LongBench, 40,
                base.model.max_seq, base.seed,
            );
            for family in [
                PlannerFamily::Bucket,
                PlannerFamily::Fcfs,
                PlannerFamily::Lookahead,
            ] {
                let mut cfg = base.clone();
                cfg.planner.family = family;
                let r = System::BucketServe.run_sim(&cfg, &trace);
                let s = Summary::from_report(
                    &format!(
                        "BucketServe/{}/ttft-{tag}/rps{rps}",
                        family.name()
                    ),
                    &r,
                    &cfg.slo,
                );
                println!("{}", s.to_json());
                let pad_eff = if r.prefill_busy_us > 0 {
                    r.prefill_useful_us / r.prefill_busy_us as f64
                } else {
                    1.0
                };
                t.row(vec![
                    format!("{tag} ({} ms)", ttft_us / 1000),
                    f1(rps),
                    family.name().to_string(),
                    f2(r.slo_attainment_class(
                        RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
                    )),
                    f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                    f2(r.slo_attainment_class(
                        RequestClass::Offline, cfg.slo.ttft_us, cfg.slo.tbt_us,
                    )),
                    f1(r.throughput_tps()),
                    f2(pad_eff),
                ]);
            }
        }
    }
    t.print(
        "planner families (40 offline LongBench @ t=0 + online Alpaca \
         stream); pad eff = useful/busy prefill time",
    );
}
