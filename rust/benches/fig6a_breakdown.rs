//! Fig. 6a — End-to-end execution-duration breakdown vs. load.
//!
//! Paper claims: decoding accounts for ≈ 90% of execution time; at RPS 32
//! prefill queueing grows; the bucketing overhead bar is barely visible
//! (< 1% of total). We decompose each BucketServe run into queue wait,
//! prefill execution, decode execution, and measured bucketing overhead.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    let cfg = SystemConfig::default();
    let n = 300;
    println!("Fig. 6a — per-request duration breakdown (BucketServe, Alpaca)\n");

    let mut t = Table::new(&[
        "client RPS", "queue ms", "prefill ms", "decode ms", "bucketing ms",
        "decode %", "bucketing %",
    ]);
    for &rps in &[8.0, 16.0, 24.0, 32.0] {
        let trace = Trace::generate(
            Dataset::Alpaca, n, rps, RequestClass::Online, cfg.model.max_seq, cfg.seed,
        );
        let report = System::BucketServe.run_sim(&cfg, &trace);
        let (q_us, pre_us, dec_us, buck_us) = report.breakdown_us();
        let total = q_us + pre_us + dec_us + buck_us;
        t.row(vec![
            f1(rps),
            f1(q_us / 1e3),
            f1(pre_us / 1e3),
            f1(dec_us / 1e3),
            format!("{:.4}", buck_us / 1e3),
            f2(dec_us / total * 100.0),
            format!("{:.4}", buck_us / total * 100.0),
        ]);
    }
    t.print("execution duration breakdown");
    println!(
        "\npaper shape: decode ≈ 90% of execution; queueing grows by RPS 32; bucketing < 1%."
    );
}
