//! Ablation: the preemption subsystem (urgency-triggered prefill abort +
//! decode KV eviction with checkpoint-and-restore) vs the priority-only
//! baseline, swept over overload levels.
//!
//! The scenario is the one priority alone cannot fix: an offline
//! LongBench backlog at t=0 keeps the prefill instances busy with
//! multi-second waves and the decode KV full, while an online Alpaca
//! stream arrives on top. Priority reorders the *queue*, but a request
//! arriving just after a wave dispatches still waits the whole wave out.
//! Preemption aborts the wave (charging the wasted FLOP-time) or evicts
//! offline KV (charging recompute) to serve the deadline instead — the
//! wasted-token columns quantify what that rescue costs.
//!
//! Timing: KV-bound LongBench waves run ~3 s, so the TTFT budget is 2 s
//! and the trigger fires at 60% of it (1.2 s) — inside the abortable half
//! of a wave, with budget left to re-prefill. Each run also emits its
//! Summary JSON on stdout (one line per run) so trajectory tooling can
//! scrape the sweep; the preempt block appears only in the
//! preemption-enabled rows.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::metrics::Summary;
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    println!("preempt_slo — preemption vs priority-only under overload\n");
    let mut base = SystemConfig::default();
    base.slo.ttft_us = 2_000_000;
    base.preempt.urgency_threshold = 0.6;
    let mut t = Table::new(&[
        "online rps", "preempt", "online SLO", "offline SLO",
        "online TTFT ms", "aborts", "evictions", "wasted tok",
        "recompute tok", "tok/s",
    ]);
    for &rps in &[4.0, 8.0, 16.0] {
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 120, rps, Dataset::LongBench, 60,
            base.model.max_seq, base.seed,
        );
        for (label, enabled) in [("off", false), ("on", true)] {
            let mut cfg = base.clone();
            cfg.preempt.enabled = enabled;
            let r = System::BucketServe.run_sim(&cfg, &trace);
            let s = Summary::from_report(
                &format!("BucketServe/preempt-{label}/rps{rps}"),
                &r,
                &cfg.slo,
            );
            println!("{}", s.to_json());
            t.row(vec![
                f1(rps),
                label.to_string(),
                f2(r.slo_attainment_class(
                    RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
                )),
                f2(r.slo_attainment_class(
                    RequestClass::Offline, cfg.slo.ttft_us, cfg.slo.tbt_us,
                )),
                f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                r.prefill_aborts.to_string(),
                r.decode_evictions.to_string(),
                r.wasted_prefill_tokens.to_string(),
                r.recompute_tokens.to_string(),
                f1(r.throughput_tps()),
            ]);
        }
    }
    t.print(
        "ablation: preemption on/off \
         (60 offline LongBench @ t=0 + online Alpaca stream)",
    );
}
