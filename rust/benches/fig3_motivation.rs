//! Fig. 3 — Batch execution time and GPU utilization across workload types.
//!
//! The paper's motivation study: under naive (static, request-level)
//! batching, Long batches dominate execution time (3a) and Mixed batches
//! leave the GPU under-utilized (3b). We reproduce it by running the
//! aggregated static-batching baseline over Short (Alpaca < 256), Long
//! (LongBench ≥ 1024) and Mixed traces on one simulated A100.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn trace_of(kind: &str, n: usize, cfg: &SystemConfig) -> Trace {
    // Filter the synthetic datasets into the paper's categories.
    let (dataset, keep): (Dataset, Box<dyn Fn(u32) -> bool>) = match kind {
        "Short" => (Dataset::Alpaca, Box::new(|l| l < 256)),
        "Long" => (Dataset::LongBench, Box::new(|l| l >= 1024)),
        _ => (Dataset::Mixed, Box::new(|_| true)),
    };
    let mut pool = Trace::batch(dataset, n * 4, RequestClass::Offline,
                                cfg.model.max_seq, cfg.seed);
    pool.requests.retain(|r| keep(r.input_len));
    pool.requests.truncate(n);
    for (i, r) in pool.requests.iter_mut().enumerate() {
        r.id = i as u64;
    }
    pool
}

fn main() {
    let mut cfg = SystemConfig::default();
    cfg.fleet.n_prefill = 1; // single-GPU motivation study
    cfg.fleet.n_decode = 1;

    println!("Fig. 3 — naive static batching across workload types\n");
    let mut t3a = Table::new(&["batch size", "Short ms", "Long ms", "Mixed ms"]);
    let mut t3b = Table::new(&["workload", "avg GPU util", "makespan s", "tok/s"]);

    for &bs in &[8usize, 16, 32] {
        let mut row = vec![bs.to_string()];
        for kind in ["Short", "Long", "Mixed"] {
            let trace = trace_of(kind, bs, &cfg);
            let report = System::Uellm.run_sim(&cfg, &trace);
            // One static batch of `bs` requests → its full execution time.
            row.push(f1(report.makespan_us as f64 / 1e3));
        }
        t3a.row(row);
    }
    t3a.print("Fig 3a — batch execution duration (one static batch)");

    for kind in ["Short", "Long", "Mixed"] {
        let trace = trace_of(kind, 64, &cfg);
        let report = System::Uellm.run_sim(&cfg, &trace);
        t3b.row(vec![
            kind.to_string(),
            f2(report.gpu_util()),
            f2(report.makespan_us as f64 / 1e6),
            f1(report.throughput_tps()),
        ]);
    }
    t3b.print("Fig 3b — average GPU utilization (static batching, 64 reqs)");

    println!("\npaper shape: Long ≫ Short in exec time; Mixed util is the lowest.");
}
