//! Runtime integration: real PJRT execution of the AOT artifacts.
//!
//! Gated on `artifacts/manifest.json` existing (run `make artifacts`);
//! each test exercises the full runtime path: HLO text → compile →
//! execute → per-request KV state → continuous decode.

use bucketserve::cluster::{
    DecodeBatch, DecodeSeq, Engine, PrefillBatch, PrefillItem,
};
use bucketserve::config::SystemConfig;
use bucketserve::coordinator::BucketServe;
use bucketserve::runtime::{artifacts_available, PjrtEngine};
use bucketserve::workload::{Request, RequestClass, Trace};

fn engine() -> Option<PjrtEngine> {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::load("artifacts").expect("engine load"))
}

#[test]
fn prefill_then_decode_generates_tokens() {
    let Some(mut e) = engine() else { return };
    let batch = PrefillBatch {
        items: vec![
            PrefillItem { id: 1, len: 12, tokens: vec![] },
            PrefillItem { id: 2, len: 30, tokens: vec![] },
        ],
        padded_len: 32,
    };
    let dur = e.prefill(&batch).unwrap();
    assert!(dur > 0, "prefill reports wall time");
    assert_eq!(e.generated(1).unwrap().len(), 1, "first token from prefill");

    for step in 0..3 {
        let d = DecodeBatch {
            seqs: vec![
                DecodeSeq { id: 1, ctx_len: 12 + 1 + step },
                DecodeSeq { id: 2, ctx_len: 30 + 1 + step },
            ],
        };
        e.decode_step(&d).unwrap();
    }
    let gen1 = e.generated(1).unwrap().to_vec();
    let gen2 = e.generated(2).unwrap().to_vec();
    assert_eq!(gen1.len(), 4);
    assert_eq!(gen2.len(), 4);
    let vocab = e.runtime().manifest.model.vocab as i32;
    assert!(gen1.iter().all(|&t| (0..vocab).contains(&t)));
    e.release(1);
    assert!(e.generated(1).is_none());
}

#[test]
fn generation_is_deterministic_across_engines() {
    let Some(mut e1) = engine() else { return };
    let Some(mut e2) = engine() else { return };
    let batch = PrefillBatch {
        items: vec![PrefillItem { id: 7, len: 20, tokens: vec![] }],
        padded_len: 32,
    };
    e1.prefill(&batch).unwrap();
    e2.prefill(&batch).unwrap();
    for step in 0..4 {
        let d = DecodeBatch {
            seqs: vec![DecodeSeq { id: 7, ctx_len: 21 + step }],
        };
        e1.decode_step(&d).unwrap();
        e2.decode_step(&d).unwrap();
    }
    assert_eq!(e1.generated(7).unwrap(), e2.generated(7).unwrap());
}

#[test]
fn batch_composition_does_not_change_tokens() {
    // Continuous batching correctness: a sequence decoded alone must
    // produce the same tokens as decoded inside a batch with others.
    let Some(mut solo) = engine() else { return };
    let Some(mut multi) = engine() else { return };

    let item = |id| PrefillItem { id, len: 16, tokens: vec![] };
    solo.prefill(&PrefillBatch { items: vec![item(1)], padded_len: 32 })
        .unwrap();
    multi
        .prefill(&PrefillBatch {
            items: vec![item(1), item(2), item(3)],
            padded_len: 32,
        })
        .unwrap();

    for step in 0..3 {
        solo.decode_step(&DecodeBatch {
            seqs: vec![DecodeSeq { id: 1, ctx_len: 17 + step }],
        })
        .unwrap();
        multi
            .decode_step(&DecodeBatch {
                seqs: vec![
                    DecodeSeq { id: 1, ctx_len: 17 + step },
                    DecodeSeq { id: 2, ctx_len: 17 + step },
                    DecodeSeq { id: 3, ctx_len: 17 + step },
                ],
            })
            .unwrap();
    }
    assert_eq!(
        solo.generated(1).unwrap(),
        multi.generated(1).unwrap(),
        "request 1's stream must not depend on batch-mates"
    );
}

#[test]
fn full_bucketserve_pipeline_on_real_engine() {
    let Some(mut e) = engine() else { return };
    let cfg = SystemConfig::tiny_pjrt();
    let requests: Vec<Request> = (0..6)
        .map(|i| {
            Request::new(i, RequestClass::Online, 10 + (i as u32) * 17 % 120, 3, 0)
        })
        .collect();
    let trace = Trace { requests };
    let report = BucketServe::new(cfg).run(&trace, &mut e);
    assert_eq!(report.completions.len(), 6);
    for c in &report.completions {
        assert!(c.finished >= c.first_token);
        assert_eq!(c.output_len, 3);
    }
    assert!(report.throughput_tps() > 0.0);
}

#[test]
fn oversized_batch_is_chunked_across_artifacts() {
    let Some(mut e) = engine() else { return };
    // 10 items > max compiled batch (8) → engine must chunk transparently.
    let items: Vec<PrefillItem> = (0..10)
        .map(|i| PrefillItem { id: 100 + i, len: 8 + i as u32, tokens: vec![] })
        .collect();
    e.prefill(&PrefillBatch { items, padded_len: 32 }).unwrap();
    for i in 0..10 {
        assert!(e.generated(100 + i).is_some(), "request {i} prefilled");
    }
    let seqs: Vec<DecodeSeq> = (0..10)
        .map(|i| DecodeSeq { id: 100 + i, ctx_len: 9 + i as u32 })
        .collect();
    e.decode_step(&DecodeBatch { seqs }).unwrap();
    for i in 0..10 {
        assert_eq!(e.generated(100 + i).unwrap().len(), 2);
    }
}
