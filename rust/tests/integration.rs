//! Integration tests: full system over the simulated cluster, paired
//! system comparisons, and cross-module invariants.

use bucketserve::baselines::System;
use bucketserve::config::{Placement, PlannerFamily, Policy, SystemConfig};
use bucketserve::coordinator::RunReport;
use bucketserve::metrics::Summary;
use bucketserve::util::prop;
use bucketserve::workload::{Dataset, Request, RequestClass, Trace};

fn run(system: System, cfg: &SystemConfig, trace: &Trace) -> RunReport {
    system.run_sim(cfg, trace)
}

#[test]
fn all_systems_complete_all_requests_on_all_datasets() {
    let cfg = SystemConfig::default();
    for dataset in [Dataset::Alpaca, Dataset::LongBench, Dataset::Mixed] {
        let trace = Trace::generate(
            dataset, 80, 8.0, RequestClass::Online, cfg.model.max_seq, 11,
        );
        for system in System::ALL {
            let r = run(system, &cfg, &trace);
            assert_eq!(
                r.completions.len(),
                trace.len(),
                "{} lost requests on {}",
                system.name(),
                dataset.name()
            );
            let mut ids: Vec<_> = r.completions.iter().map(|c| c.id).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), trace.len(), "{} duplicated", system.name());
        }
    }
}

#[test]
fn headline_throughput_ordering_holds() {
    // Fig. 5a direction: BucketServe > DistServe > UELLM on heterogeneous
    // offline load.
    let cfg = SystemConfig::default();
    let trace = Trace::batch(Dataset::Mixed, 256, RequestClass::Offline, 4096, 12);
    let tb = run(System::BucketServe, &cfg, &trace).throughput_tps();
    let td = run(System::DistServe, &cfg, &trace).throughput_tps();
    let tu = run(System::Uellm, &cfg, &trace).throughput_tps();
    assert!(tb > td, "BucketServe {tb} <= DistServe {td}");
    assert!(td > tu, "DistServe {td} <= UELLM {tu}");
    // The paper's headline factor vs UELLM is 3.58×. Our UELLM-like
    // baseline shares the memory-safe admission (only the paper's
    // qualitative deficiencies are modelled), so it is conservatively
    // strong; require a clear directional win (see EXPERIMENTS.md).
    assert!(tb / tu > 1.2, "BucketServe/UELLM only {:.2}×", tb / tu);
}

#[test]
fn slo_capacity_ordering_holds_on_mixed() {
    // Fig. 5d direction: at high load BucketServe retains more SLO
    // attainment than DistServe on heterogeneous traffic.
    let cfg = SystemConfig::default();
    let trace = Trace::generate(
        Dataset::Mixed, 250, 24.0, RequestClass::Online, cfg.model.max_seq, 13,
    );
    let ab = run(System::BucketServe, &cfg, &trace)
        .slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
    let ad = run(System::DistServe, &cfg, &trace)
        .slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
    assert!(
        ab >= ad,
        "BucketServe attainment {ab} < DistServe {ad} at high load"
    );
}

#[test]
fn gpu_util_ordering_holds() {
    let cfg = SystemConfig::default();
    let trace = Trace::batch(Dataset::Mixed, 192, RequestClass::Offline, 4096, 14);
    let ub = run(System::BucketServe, &cfg, &trace).gpu_util();
    let uu = run(System::Uellm, &cfg, &trace).gpu_util();
    assert!(ub > uu, "BucketServe util {ub} <= UELLM {uu}");
}

#[test]
fn bucketing_overhead_under_one_percent_everywhere() {
    let cfg = SystemConfig::default();
    for rps in [8.0, 32.0] {
        let trace = Trace::generate(
            Dataset::Mixed, 150, rps, RequestClass::Online, cfg.model.max_seq, 15,
        );
        let r = run(System::BucketServe, &cfg, &trace);
        let overhead_us = r.bucket_overhead_ns as f64 / 1e3;
        assert!(
            overhead_us < 0.01 * r.makespan_us as f64,
            "overhead {overhead_us}µs at rps {rps}"
        );
    }
}

#[test]
fn policies_trade_latency_for_throughput() {
    let base = SystemConfig::default();
    let trace = Trace::batch(Dataset::Mixed, 200, RequestClass::Offline, 4096, 16);
    let mut results = Vec::new();
    for policy in [Policy::Sjf, Policy::Ljf] {
        let mut cfg = base.clone();
        cfg.scheduler.policy = policy;
        let r = run(System::BucketServe, &cfg, &trace);
        let mean_e2e = r.mean_e2e_us();
        results.push((policy, r.throughput_tps(), mean_e2e));
    }
    // SJF must deliver lower mean E2E than LJF (short jobs first).
    assert!(
        results[0].2 < results[1].2,
        "SJF mean E2E {} >= LJF {}",
        results[0].2,
        results[1].2
    );
}

#[test]
fn shards_1_summary_json_is_byte_identical_to_legacy() {
    // The sharding refactor must be behavior-preserving until enabled:
    // with shards = 1 (the default) the placement policy and the steal
    // flag are inert, so every such configuration must produce the exact
    // same schedule — asserted at the strongest observable level, the
    // Summary JSON byte string. The preemption subsystem extends the same
    // contract: with `preempt.enabled = false` (the default) every other
    // preemption knob is inert too, however aggressive, across all the
    // sharding/placement settings swept here. The TBT-admission subsystem
    // extends it again: with `admission.enabled = false` (the default)
    // its knobs are equally inert and no TBT key appears in the JSON,
    // even though gap measurement itself runs. The prefix-cache subsystem
    // is the newest party to the contract: with `prefix.enabled = false`
    // (the default) no cache is built, no stamp ever carries nonzero
    // cached/shared tokens, and no prefix key appears in the JSON — even
    // under the `prefix_affinity` placement (which falls back to
    // join-shortest-KV) and aggressive block/frac knobs. Chunked prefill
    // joins last: with `chunk.enabled = false` (the default) the slicer
    // never fires, no batch parks, no decode iteration is hybrid-priced,
    // and no chunk key appears in the JSON, however aggressive the
    // slice/hybrid/interleave knobs behind the switch. The planner block
    // joins the same contract from the other side: its master switch is
    // `planner.family`, and with the default `bucket` family the
    // lookahead-only knobs (window, commit margin, offline horizon) are
    // inert however aggressively armed. bucket_overhead_ns is the one
    // wall-clock (hence nondeterministic) field and is normalized before
    // comparison; everything else (makespans, per-class SLOs, counts) is
    // virtual-time deterministic.
    let trace = Trace::mixed_classes(
        Dataset::Alpaca, 40, 8.0, Dataset::LongBench, 20, 4096, 33,
    );
    let summary = |system: System, cfg: &SystemConfig| {
        let mut r = system.run_sim(cfg, &trace);
        r.bucket_overhead_ns = 0;
        Summary::from_report(system.name(), &r, &cfg.slo)
            .to_json()
            .to_string()
    };
    for system in [System::BucketServe, System::DistServe] {
        let baseline = summary(system, &SystemConfig::default());
        assert!(
            !baseline.contains("n_shards"),
            "shards=1 must not grow the Summary JSON: {baseline}"
        );
        assert!(
            !baseline.contains("prefill_aborts")
                && !baseline.contains("evicted_kv_tokens"),
            "preempt disabled must not grow the Summary JSON: {baseline}"
        );
        assert!(
            !baseline.contains("tbt_attain")
                && !baseline.contains("tbt_evictions")
                && !baseline.contains("admission_deferrals"),
            "admission disabled must not grow the Summary JSON: {baseline}"
        );
        assert!(
            !baseline.contains("prefix_hit")
                && !baseline.contains("prefix_evictions")
                && !baseline.contains("prefix_resident_tokens"),
            "prefix disabled must not grow the Summary JSON: {baseline}"
        );
        assert!(
            !baseline.contains("chunk_sliced_batches")
                && !baseline.contains("chunk_slices")
                && !baseline.contains("chunk_yields")
                && !baseline.contains("chunk_hybrid_iters"),
            "chunk disabled must not grow the Summary JSON: {baseline}"
        );
        for placement in [
            Placement::LeastLoaded,
            Placement::JoinShortestKv,
            Placement::Hash,
            Placement::PrefixAffinity,
        ] {
            for steal in [false, true] {
                let mut cfg = SystemConfig::default();
                cfg.sharding.shards = 1;
                cfg.sharding.placement = placement;
                cfg.sharding.steal = steal;
                // Arm every preemption knob except the master switch: a
                // disabled spec must be byte-for-byte inert.
                cfg.preempt.urgency_threshold = 0.01;
                cfg.preempt.max_abort_progress = 1.0;
                cfg.preempt.max_evictions = 64;
                // Likewise every admission knob except its master switch.
                cfg.admission.slack_margin = 0.99;
                cfg.admission.offline_tbt_factor = 1.0;
                cfg.admission.max_evictions = 64;
                // And every prefix knob except its master switch.
                cfg.prefix.block = 1;
                cfg.prefix.cache_frac = 1.0;
                // And every chunking knob except its master switch: a
                // one-token slice would shred every prefill if armed.
                cfg.chunk.slice_tokens = 1;
                cfg.chunk.hybrid = false;
                cfg.chunk.interleave = false;
                // And every lookahead knob except the family selector
                // (the planner block's master switch): under the default
                // bucket family the window/margin/horizon values must
                // never be consulted.
                cfg.planner.window = 1;
                cfg.planner.commit_margin_us = 1;
                cfg.planner.offline_horizon_us = 123_456;
                // And the executor: with one shard, any thread count
                // resolves to the sequential path, so `threads = 1`
                // stays byte-identical to the pre-executor scheduler.
                cfg.executor.threads = 8;
                assert_eq!(
                    summary(system, &cfg),
                    baseline,
                    "{} diverged with shards=1 placement={} steal={steal} \
                     preempt-admission-prefix-and-chunk-knobs-armed",
                    system.name(),
                    placement.name(),
                );
            }
        }
    }
}

#[test]
fn executor_determinism_matrix_across_threads_and_features() {
    // The parallel executor's acceptance criterion, asserted at the
    // strongest observable level: for every seed and feature combination,
    // a run with `executor.threads = N` (N > 1, including thread-per-
    // shard) produces Summary JSON byte-identical to the sequential
    // `threads = 1` run. Only `bucket_overhead_ns` — the one wall-clock
    // field — is normalized. The matrix crosses the subsystems whose
    // scheduling the executor must not perturb: priority, preemption,
    // TBT admission, and the prefix cache, over a sharded fleet with
    // stealing on. Prefix-armed rows run a multi-turn trace under the
    // affinity placement so dispatch acquisitions, pin releases, and LRU
    // evictions all actually fire — all of which mutate cache state on
    // the merge loop and must be invisible to the thread count. Chunked
    // prefill stretches one logical prefill across many events (each
    // slice boundary a sync barrier for the workers), park/resume moves
    // in-flight state between the shard and the fleet on the merge loop,
    // and hybrid pricing keys off cross-fleet state. The planner family
    // is the newest axis: lookahead rows swap every shard's planner for
    // the deadline-sorted hold-capable one, whose held plans (plan →
    // None with a non-empty queue) and deadline-order drains must
    // reproduce the sequential bytes under every thread count and
    // planning mode — speculated hold decisions are pure functions of
    // (snapshot, now, headroom), so offloaded planning may not perturb
    // them. Feature tuples: (priority, preempt, admission, prefix,
    // chunk, lookahead).
    let features: [(bool, bool, bool, bool, bool, bool); 13] = [
        (false, false, false, false, false, false),
        (true, false, false, false, false, false),
        (true, true, false, false, false, false),
        (true, false, true, false, false, false),
        (true, true, true, false, false, false),
        (false, false, false, true, false, false),
        (true, true, true, true, false, false),
        (false, false, false, false, true, false),
        (true, true, false, false, true, false),
        (true, true, true, true, true, false),
        (false, false, false, false, false, true),
        (false, false, false, false, true, true),
        (true, true, true, true, true, true),
    ];
    for seed in [33u64, 77] {
        let mixed = Trace::mixed_classes(
            Dataset::Alpaca, 30, 10.0, Dataset::LongBench, 15, 4096, seed,
        );
        let turns = Trace::multi_turn(Dataset::Alpaca, 8, 4, 12.0, 4096, seed);
        for &(priority, preempt, admission, prefix, chunk, lookahead) in
            &features
        {
            let trace = if prefix { &turns } else { &mixed };
            let mut base = SystemConfig::default();
            base.fleet.n_prefill = 2;
            base.fleet.n_decode = 4;
            base.sharding.shards = 0; // one shard per decode instance
            base.sharding.placement = if prefix {
                Placement::PrefixAffinity
            } else {
                Placement::Hash
            };
            base.sharding.steal = true;
            base.priority.enabled = priority;
            base.preempt.enabled = preempt;
            base.admission.enabled = admission;
            base.prefix.enabled = prefix;
            base.chunk.enabled = chunk;
            base.chunk.slice_tokens = 512;
            if lookahead {
                base.planner.family = PlannerFamily::Lookahead;
                // A small window and short margin keep both branches of
                // the hold gate live on these traces.
                base.planner.window = 8;
                base.planner.commit_margin_us = 20_000;
            }
            // Tight budgets so the armed subsystems actually fire inside
            // the matrix (aborts, evictions, deferrals, cache churn), not
            // just idle. The small cache_frac forces LRU evictions.
            base.slo.ttft_us = 2_000_000;
            base.slo.tbt_us = 40_000;
            base.preempt.urgency_threshold = 0.5;
            base.prefix.cache_frac = 0.05;
            let summary = |threads: u32, plan_offload: bool| {
                let mut cfg = base.clone();
                cfg.executor.threads = threads;
                cfg.executor.plan_offload = plan_offload;
                let mut r = System::BucketServe.run_sim(&cfg, trace);
                let resolved = r.executor_threads;
                let plans = r.executor_parallel_plans;
                r.bucket_overhead_ns = 0; // wall clock: the one normalized field
                let json = Summary::from_report("BucketServe", &r, &cfg.slo)
                    .to_json()
                    .to_string();
                (resolved, plans, json)
            };
            let (t1, p1, sequential) = summary(1, true);
            assert_eq!(t1, 1);
            assert_eq!(p1, 0, "sequential mode must not fan out plans");
            // The parallel-planning axis: threads × plan_offload. Every
            // cell — planning speculated on workers or inline on the
            // merge loop — must reproduce the sequential bytes.
            for threads in [2u32, 0] {
                for plan_offload in [true, false] {
                    let (tn, plans, parallel) = summary(threads, plan_offload);
                    assert!(tn > 1, "matrix config must actually go parallel");
                    assert_eq!(
                        plans > 0,
                        plan_offload,
                        "plan fan-out must follow executor.plan_offload"
                    );
                    assert_eq!(
                        parallel, sequential,
                        "threads={threads} plan_offload={plan_offload} \
                         diverged from sequential (priority={priority} \
                         preempt={preempt} admission={admission} \
                         prefix={prefix} chunk={chunk} \
                         lookahead={lookahead} seed={seed})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_executor_determinism_under_cross_shard_stress() {
    // Satellite stress test: randomized traces exercising steals, prefill
    // aborts, and checkpoint-restores under the parallel executor. Pins
    // (a) request and token conservation, and (b) that stamped
    // `QueuedReq::tbt_us` budgets and TTFT deadlines survive cross-shard
    // transfer intact — asserted as exact equality of the parallel run's
    // completion records and per-class gap/violation books against the
    // sequential run's.
    prop::check("parallel executor ≡ sequential", 15, |g| {
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = g.usize(1, 3) as u32;
        cfg.fleet.n_decode = g.usize(2, 4) as u32;
        cfg.sharding.shards = 0;
        cfg.sharding.placement = *g.pick(&[
            Placement::LeastLoaded,
            Placement::JoinShortestKv,
            Placement::Hash,
        ]);
        cfg.sharding.steal = true;
        cfg.priority.enabled = true;
        cfg.preempt.enabled = g.bool();
        cfg.preempt.urgency_threshold = g.f64_in(0.05, 1.0);
        cfg.admission.enabled = g.bool();
        cfg.admission.slack_margin = g.f64_in(0.0, 0.5);
        // Random chunking specs: sliced prefills multiply the event count
        // per batch, park/resume reorders dispatch, and hybrid pricing
        // reads cross-fleet state — all must be thread-count-invisible.
        cfg.chunk.enabled = g.bool();
        cfg.chunk.slice_tokens = g.usize(64, 2048) as u32;
        cfg.chunk.hybrid = g.bool();
        cfg.chunk.interleave = g.bool();
        // Random parallel-planning mode: offloaded speculation and
        // inline planning must both reproduce the sequential schedule
        // (the sequential run below never consults this flag).
        cfg.executor.plan_offload = g.bool();
        cfg.slo.ttft_us = g.u64(1_000_000, 20_000_000);
        cfg.slo.tbt_us = g.u64(25_000, 120_000);
        let trace = Trace::mixed_classes(
            Dataset::Alpaca,
            g.usize(10, 40),
            g.f64_in(2.0, 30.0),
            Dataset::LongBench,
            g.usize(5, 20),
            4096,
            g.u64(0, 1 << 30),
        )
        .stamp_tbt(g.u64(0, 60_000), g.u64(0, 400_000));
        let total = trace.len();
        let run = |threads: u32| {
            let mut c = cfg.clone();
            c.executor.threads = threads;
            System::BucketServe.run_sim(&c, &trace)
        };
        let seq_r = run(1);
        let par = run(if g.bool() { 2 } else { 0 });
        assert!(par.executor_threads > 1, "stress run must be parallel");

        // Conservation on the parallel run in its own right.
        assert_eq!(par.completions.len(), total);
        assert!(par.error.is_none(), "{:?}", par.error);
        let mut ids: Vec<_> = par.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "exactly-once completion");
        let in_tokens: u64 =
            trace.requests.iter().map(|q| q.total_len() as u64).sum();
        let out_tokens: u64 = par
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        assert_eq!(in_tokens, out_tokens, "token books");

        // Exact equivalence with the sequential schedule: every
        // completion record (ids, classes, prompt/output splits, TTFT
        // and finish timestamps) and the full TBT accounting.
        let key = |r: &RunReport| {
            let mut v: Vec<_> = r
                .completions
                .iter()
                .map(|c| {
                    (
                        c.id,
                        c.class,
                        c.input_len,
                        c.output_len,
                        c.arrival,
                        c.first_token,
                        c.finished,
                        c.padded_len,
                    )
                })
                .collect();
            v.sort_by_key(|t| t.0);
            v
        };
        assert_eq!(key(&par), key(&seq_r), "completion records diverged");
        assert_eq!(par.tbt_gaps_online_us, seq_r.tbt_gaps_online_us);
        assert_eq!(par.tbt_gaps_offline_us, seq_r.tbt_gaps_offline_us);
        assert_eq!(par.tbt_violations_online, seq_r.tbt_violations_online);
        assert_eq!(par.tbt_violations_offline, seq_r.tbt_violations_offline);
        assert_eq!(par.steals, seq_r.steals);
        assert_eq!(par.prefill_aborts, seq_r.prefill_aborts);
        assert_eq!(par.decode_evictions, seq_r.decode_evictions);
        assert_eq!(par.tbt_evictions, seq_r.tbt_evictions);
        assert_eq!(par.admission_deferrals, seq_r.admission_deferrals);
        assert_eq!(par.makespan_us, seq_r.makespan_us);
        assert_eq!(par.decode_iters, seq_r.decode_iters);
        assert_eq!(par.prefill_batches, seq_r.prefill_batches);
        assert_eq!(par.chunk_sliced_batches, seq_r.chunk_sliced_batches);
        assert_eq!(par.chunk_slices, seq_r.chunk_slices);
        assert_eq!(par.chunk_yields, seq_r.chunk_yields);
        assert_eq!(par.chunk_hybrid_iters, seq_r.chunk_hybrid_iters);
        // Plan rounds are a function of the schedule, counted by the
        // consume stage both modes share — so they match exactly (unlike
        // invalidations, which only exist under eager speculation).
        assert_eq!(par.executor_plan_rounds, seq_r.executor_plan_rounds);
    });
}

#[test]
fn deferral_uses_boundary_to_boundary_accounting() {
    // ROADMAP follow-up regression: the deferral gate used to evaluate a
    // mid-iteration dispatch against `last_token + budget − now`,
    // charging time already elapsed since a resident's last boundary
    // against the incoming batch's projected iteration — time the gap
    // clock re-anchors away at the boundary the batch actually joins.
    // Under a 30 ms budget (27 ms effective) and a ~24 ms two-sequence
    // iteration, that old accounting deferred any dispatch landing more
    // than ~3 ms after a boundary; boundary-to-boundary accounting
    // admits it, at equal (here: perfect) attainment. Request 1 arrives
    // while request 0 is mid-decode, so its dispatch is exactly such a
    // mid-iteration decision.
    let mut cfg = SystemConfig::default();
    cfg.fleet.n_prefill = 1;
    cfg.fleet.n_decode = 1;
    cfg.slo.tbt_us = 30_000;
    cfg.admission.enabled = true;
    let trace = Trace {
        requests: vec![
            Request::new(0, RequestClass::Online, 200, 80, 0),
            Request::new(1, RequestClass::Online, 200, 30, 1_200_000),
        ],
    };
    let r = System::BucketServe.run_sim(&cfg, &trace);
    assert_eq!(r.completions.len(), 2);
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(
        r.admission_deferrals, 0,
        "a projected iteration inside every resident's budget must not \
         defer, wherever in the boundary cycle dispatch lands"
    );
    assert_eq!(
        r.tbt_violations_online, 0,
        "equal attainment: admitting the batch costs nothing"
    );
    assert_eq!(r.tbt_evictions, 0);
}

#[test]
fn prop_sharded_serving_conserves_requests() {
    // The end-to-end mirror of the shard-layer conservation property:
    // random fleets, shard counts, placements, steal settings, and
    // preemption specs never lose or duplicate a request, for both
    // planner families. Preemption is the interesting half: every
    // aborted prefill batch and every evicted (checkpoint-restored)
    // decode sequence must still complete exactly once, and the
    // aggressive random thresholds make triggers fire across many of the
    // sampled mixed-class cases.
    prop::check("sharded serving conserves requests", 25, |g| {
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = g.usize(1, 3) as u32;
        cfg.fleet.n_decode = g.usize(1, 4) as u32;
        cfg.sharding.shards = g.usize(0, 4) as u32;
        cfg.sharding.placement = *g.pick(&[
            Placement::LeastLoaded,
            Placement::JoinShortestKv,
            Placement::Hash,
        ]);
        cfg.sharding.steal = g.bool();
        cfg.priority.enabled = g.bool();
        cfg.preempt.enabled = g.bool();
        cfg.preempt.urgency_threshold = g.f64_in(0.05, 1.2);
        cfg.preempt.max_abort_progress = g.f64_in(0.1, 1.0);
        cfg.preempt.max_evictions = g.usize(1, 8) as u32;
        // The TBT-admission layer must conserve too: random tight budgets
        // make the deferral gate and the evict pass fire across many of
        // the sampled cases (30–60 ms brackets the modeled iteration
        // time), and every TBT-evicted sequence must still complete once.
        cfg.admission.enabled = g.bool();
        cfg.admission.slack_margin = g.f64_in(0.0, 0.5);
        cfg.admission.max_evictions = g.usize(1, 8) as u32;
        cfg.slo.tbt_us = g.u64(25_000, 120_000);
        // The prefix cache must conserve too: random block sizes and
        // tight budgets churn the LRU, and deduplicated KV books (the
        // cache holding shared-block reservations on requests' behalf)
        // must still land every completion with its original token split.
        cfg.prefix.enabled = g.bool();
        cfg.prefix.block = g.usize(8, 128) as u32;
        cfg.prefix.cache_frac = g.f64_in(0.02, 0.9);
        if cfg.prefix.enabled && g.bool() {
            cfg.sharding.placement = Placement::PrefixAffinity;
        }
        // Chunked prefill must conserve too: random (often tiny) slice
        // sizes shred long prefills into many slices, parking and
        // resuming across the other subsystems' aborts and evictions —
        // every sliced batch must still complete exactly once with its
        // original token split.
        cfg.chunk.enabled = g.bool();
        cfg.chunk.slice_tokens = g.usize(32, 4096) as u32;
        cfg.chunk.hybrid = g.bool();
        cfg.chunk.interleave = g.bool();
        let n = g.usize(5, 60);
        let rps = g.f64_in(1.0, 40.0);
        let seed = g.u64(0, 1 << 30);
        // Mixed-class traces exercise the eviction path (victims are
        // offline-only); single-class online traces exercise the abort
        // path against less-urgent online batches; multi-turn traces
        // carry the lineage stamps the prefix cache feeds on.
        let trace = match g.usize(0, 2) {
            0 => Trace::mixed_classes(
                Dataset::Alpaca, n, rps, Dataset::LongBench, g.usize(5, 25),
                cfg.model.max_seq, seed,
            ),
            1 => Trace::generate(
                Dataset::Mixed, n, rps, RequestClass::Online,
                cfg.model.max_seq, seed,
            ),
            _ => Trace::multi_turn(
                Dataset::Alpaca, (n / 4).max(1), 4, rps,
                cfg.model.max_seq, seed,
            ),
        };
        let total = trace.len();
        let sys = *g.pick(&[System::BucketServe, System::DistServe]);
        let r = sys.run_sim(&cfg, &trace);
        assert_eq!(r.completions.len(), total, "{} lost requests", sys.name());
        let mut ids: Vec<_> = r.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), total, "{} duplicated requests", sys.name());
        assert_eq!(
            r.shard_routed.iter().sum::<u64>(),
            total as u64,
            "routing accounting broken"
        );
        if !cfg.preempt.enabled {
            assert_eq!(r.prefill_aborts + r.decode_evictions, 0);
        }
        if !cfg.admission.enabled {
            assert_eq!(r.admission_deferrals + r.tbt_evictions, 0);
        }
        if !cfg.chunk.enabled {
            assert_eq!(
                r.chunk_sliced_batches
                    + r.chunk_slices
                    + r.chunk_yields
                    + r.chunk_hybrid_iters,
                0,
                "{} chunk counters must stay silent when disabled",
                sys.name()
            );
        }
        if cfg.prefix.enabled {
            // Every LRU eviction frees exactly one block: the token
            // counter and the event counter must stay in lockstep or the
            // deduplicated KV books have drifted.
            assert_eq!(
                r.prefix_evicted_tokens,
                r.prefix_evictions * cfg.prefix.block as u64,
                "{} eviction books",
                sys.name()
            );
        } else {
            assert_eq!(
                r.prefix_hits
                    + r.prefix_misses
                    + r.prefix_hit_tokens
                    + r.prefix_evictions
                    + r.prefix_resident_tokens,
                0,
                "{} prefix counters must stay silent when disabled",
                sys.name()
            );
        }
        for c in &r.completions {
            assert!(c.first_token >= c.arrival);
            assert!(c.finished >= c.first_token);
        }
        // Token conservation holds through abort/requeue and
        // evict/recompute: completions carry the original prompt/output
        // split whatever was replayed in between.
        let in_tokens: u64 =
            trace.requests.iter().map(|q| q.total_len() as u64).sum();
        let out_tokens: u64 = r
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        assert_eq!(in_tokens, out_tokens, "{} token books", sys.name());
    });
}

#[test]
fn tbt_admission_rescues_online_tbt_under_decode_oversubscription() {
    // The admission subsystem's acceptance scenario. One decode instance,
    // a 30 ms per-token budget: a lone batch's iteration is weight-read
    // bound (~24 ms on the modeled A100 serving 13B) and fits, but a
    // KV-saturated instance (~14k context tokens from a LongBench
    // backlog) iterates at ~35 ms — every online sequence sharing that
    // continuous batch then misses its inter-token budget on every
    // token, and nothing TTFT-side (priority, preemption) can help,
    // because the offending work is already *decoding*. With admission
    // enabled, the evict trigger sheds offline context at the boundary
    // until the projected iteration fits, and the deferral gate keeps
    // requeued offline work off the instance while online sequences are
    // resident.
    let mut cfg = SystemConfig::default();
    cfg.fleet.n_prefill = 1;
    cfg.fleet.n_decode = 1;
    cfg.slo.tbt_us = 30_000;
    let trace = Trace::mixed_classes(
        Dataset::Alpaca, 40, 4.0, Dataset::LongBench, 12, cfg.model.max_seq, 61,
    );
    let run = |enabled: bool| {
        let mut c = cfg.clone();
        c.admission.enabled = enabled;
        System::BucketServe.run_sim(&c, &trace)
    };
    let off = run(false);
    let on = run(true);

    // Conservation first: deferral and TBT eviction must never lose or
    // duplicate a request.
    for (r, label) in [(&off, "off"), (&on, "on")] {
        assert_eq!(r.completions.len(), trace.len(), "admission-{label}");
        assert!(r.error.is_none(), "admission-{label}: {:?}", r.error);
        let mut ids: Vec<_> = r.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "admission-{label} exactly-once");
    }
    assert!(!off.admission_enabled && on.admission_enabled);

    // The scenario must actually stress TBT (otherwise the test is
    // vacuous) and the mechanism must actually engage.
    assert!(
        off.tbt_violations_online > 0,
        "oversubscription this deliberate must violate online TBT"
    );
    assert!(
        on.admission_deferrals + on.tbt_evictions > 0,
        "admission must defer or evict under this overload"
    );

    // ...and the whole point: online inter-token pacing is rescued.
    let attain = |r: &RunReport| r.tbt_attainment_class(RequestClass::Online);
    assert!(
        attain(&on) > attain(&off),
        "online TBT attainment not rescued: on {} vs off {}",
        attain(&on),
        attain(&off)
    );
    let mean_gap = |r: &RunReport| {
        let g = r.tbt_gaps_class(RequestClass::Online);
        g.iter().sum::<u64>() as f64 / g.len().max(1) as f64
    };
    assert!(
        mean_gap(&on) < mean_gap(&off),
        "online mean inter-token gap not reduced: on {} vs off {}",
        mean_gap(&on),
        mean_gap(&off)
    );
    // TBT evictions keep their own books, never preemption's
    // (preemption is disabled here), and carry recompute debt.
    assert_eq!(on.decode_evictions, 0);
    assert_eq!(on.evicted_kv_tokens, 0);
    assert_eq!(on.recompute_tokens, 0);
    if on.tbt_evictions > 0 {
        assert!(on.tbt_evicted_kv_tokens > 0 && on.tbt_recompute_tokens > 0);
    }
}

#[test]
fn prefix_cache_hit_reduces_prefill_cost() {
    // The prefix subsystem's acceptance scenario: multi-turn chat
    // sessions whose growing conversation prefixes are the cache's food,
    // over a sharded fleet under deliberate backlog (so makespan tracks
    // total prefill work, not arrival pacing). Three claims:
    //
    //  1. Arming the cache cuts measured prefill GPU time — turns are
    //     priced on their uncached suffix only.
    //  2. `prefix_affinity` placement beats both lineage-blind policies
    //     (`hash`, `least_loaded`) on cache hit rate AND throughput:
    //     keeping a session's turns on the instance that already holds
    //     their KV is what converts shared context into hits.
    //  3. The hit/eviction counters stay consistent with the
    //     deduplicated KV accounting, and conservation holds throughout.
    let mut base = SystemConfig::default();
    base.fleet.n_prefill = 2;
    base.fleet.n_decode = 2;
    base.sharding.shards = 0; // one scheduler shard per decode instance
    base.slo.ttft_us = 30_000_000; // backlog run: TTFT is not the subject
    let trace = Trace::multi_turn(
        Dataset::Alpaca, 16, 6, 32.0, base.model.max_seq, 71,
    );
    let run_with = |placement: Placement, enabled: bool| {
        let mut cfg = base.clone();
        cfg.sharding.placement = placement;
        cfg.prefix.enabled = enabled;
        System::BucketServe.run_sim(&cfg, &trace)
    };
    let off = run_with(Placement::PrefixAffinity, false);
    let aff = run_with(Placement::PrefixAffinity, true);
    let hash = run_with(Placement::Hash, true);
    let ll = run_with(Placement::LeastLoaded, true);

    // Conservation first, on every variant.
    for (r, label) in
        [(&off, "off"), (&aff, "affinity"), (&hash, "hash"), (&ll, "ll")]
    {
        assert_eq!(r.completions.len(), trace.len(), "prefix-{label}");
        assert!(r.error.is_none(), "prefix-{label}: {:?}", r.error);
        let mut ids: Vec<_> = r.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "prefix-{label} exactly-once");
        let in_tokens: u64 =
            trace.requests.iter().map(|q| q.total_len() as u64).sum();
        let out_tokens: u64 = r
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        assert_eq!(in_tokens, out_tokens, "prefix-{label} token books");
    }
    assert!(!off.prefix_enabled && aff.prefix_enabled);
    assert_eq!(off.prefix_hits + off.prefix_misses + off.prefix_hit_tokens, 0);

    // Claim 1: cache hits shrink the priced prefill.
    assert!(aff.prefix_hits > 0 && aff.prefix_hit_tokens > 0);
    assert!(
        aff.prefill_busy_us < off.prefill_busy_us,
        "cache hits must cut prefill GPU time: on {} vs off {}",
        aff.prefill_busy_us,
        off.prefill_busy_us
    );

    // Claim 2: affinity placement beats lineage-blind placement on hit
    // rate and throughput at equal cache configuration.
    let hit_rate = |r: &RunReport| {
        r.prefix_hits as f64 / (r.prefix_hits + r.prefix_misses).max(1) as f64
    };
    for (r, label) in [(&hash, "hash"), (&ll, "least_loaded")] {
        assert!(
            hit_rate(&aff) > hit_rate(r),
            "affinity hit rate {} <= {label} {}",
            hit_rate(&aff),
            hit_rate(r)
        );
        assert!(
            aff.throughput_tps() > r.throughput_tps(),
            "affinity tok/s {} <= {label} {}",
            aff.throughput_tps(),
            r.throughput_tps()
        );
    }

    // Claim 3: eviction counters in lockstep (one block per eviction).
    assert_eq!(
        aff.prefix_evicted_tokens,
        aff.prefix_evictions * base.prefix.block as u64
    );
}

#[test]
fn deterministic_given_seed() {
    let cfg = SystemConfig::default();
    let t1 = Trace::generate(Dataset::Mixed, 60, 8.0, RequestClass::Online, 4096, 17);
    let t2 = Trace::generate(Dataset::Mixed, 60, 8.0, RequestClass::Online, 4096, 17);
    let r1 = run(System::BucketServe, &cfg, &t1);
    let r2 = run(System::BucketServe, &cfg, &t2);
    assert_eq!(r1.completions.len(), r2.completions.len());
    assert_eq!(r1.makespan_us, r2.makespan_us);
    assert_eq!(r1.prefill_batches, r2.prefill_batches);
    assert_eq!(r1.decode_iters, r2.decode_iters);
}

#[test]
fn prop_no_request_lost_under_random_conditions() {
    prop::check("serving conserves requests", 25, |g| {
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = g.usize(1, 3) as u32;
        cfg.fleet.n_decode = g.usize(1, 3) as u32;
        cfg.scheduler.theta = g.f64_in(0.2, 0.9);
        let n = g.usize(5, 60);
        let rps = g.f64_in(1.0, 40.0);
        let dataset = *g.pick(&[Dataset::Alpaca, Dataset::LongBench, Dataset::Mixed]);
        let seed = g.u64(0, 1 << 30);
        let trace = Trace::generate(
            dataset, n, rps, RequestClass::Online, cfg.model.max_seq, seed,
        );
        let sys = *g.pick(&[System::BucketServe, System::DistServe, System::Uellm]);
        let r = sys.run_sim(&cfg, &trace);
        assert_eq!(r.completions.len(), n, "{} lost requests", sys.name());
        for c in &r.completions {
            assert!(c.first_token >= c.arrival);
            assert!(c.finished >= c.first_token);
        }
    });
}

#[test]
fn prop_completion_token_conservation() {
    prop::check("token counts preserved", 25, |g| {
        let cfg = SystemConfig::default();
        let n = g.usize(5, 50);
        let seed = g.u64(0, 1 << 30);
        let trace =
            Trace::batch(Dataset::Mixed, n, RequestClass::Offline, 4096, seed);
        let r = System::BucketServe.run_sim(&cfg, &trace);
        let in_tokens: u64 =
            trace.requests.iter().map(|q| q.total_len() as u64).sum();
        let out_tokens: u64 = r
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        assert_eq!(in_tokens, out_tokens);
    });
}
