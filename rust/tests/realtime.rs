//! Loopback integration tests for the realtime serving path: a real
//! `RealtimeServer` on an ephemeral port, scripted NDJSON clients over
//! real sockets.
//!
//! Covers the PR's acceptance demands end to end: streamed completions
//! for mixed request classes (ordered lines, monotone timestamps),
//! `health`/`loads` introspection under load, and a mid-stream
//! connection kill that must surface as exactly one client abort with
//! zero leaked KV reservations.
//!
//! Runs are pace-compressed (`realtime.pace`), so each test finishes in
//! well under a second of wall time while exercising the identical
//! wall-clock code path.

use bucketserve::config::SystemConfig;
use bucketserve::metrics::Summary;
use bucketserve::server::realtime::RealtimeServer;
use bucketserve::server::TcpClient;
use bucketserve::util::json::Json;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

fn paced_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.realtime.pace = 50_000.0;
    cfg
}

fn spawn_server(cfg: SystemConfig) -> (String, thread::JoinHandle<Summary>) {
    let (btx, brx) = mpsc::channel();
    let handle = thread::spawn(move || {
        RealtimeServer::new(cfg)
            .serve("127.0.0.1:0", move |a| {
                let _ = btx.send(a);
            })
            .unwrap()
    });
    (brx.recv().unwrap(), handle)
}

fn op(name: &str) -> Json {
    Json::obj(vec![("op", Json::from(name))])
}

fn submit(input: u64, output: u64, class: &str) -> Json {
    Json::obj(vec![
        ("op", Json::from("submit")),
        ("input_len", Json::from(input)),
        ("output_len", Json::from(output)),
        ("class", Json::from(class)),
    ])
}

/// Submit one request and consume its whole stream; returns
/// `(token_count, last_at_us)` after asserting line ordering.
fn run_one_stream(c: &mut TcpClient, input: u64, output: u64, class: &str) -> (u64, u64) {
    let ack = c.call(&submit(input, output, class)).unwrap();
    assert_eq!(ack.get("ok").as_bool(), Some(true), "{ack}");
    let id = ack.get("id").as_u64().unwrap();
    let (mut tokens, mut last_seq, mut last_at) = (0u64, 0u64, 0u64);
    loop {
        let j = c.read_line().unwrap();
        assert_eq!(j.get("id").as_u64(), Some(id), "cross-stream line: {j}");
        if j.get("done").as_bool() == Some(true) {
            assert_eq!(j.get("output_len").as_u64(), Some(output), "{j}");
            assert!(j.get("ttft_us").as_u64().unwrap() > 0, "{j}");
            return (tokens, last_at);
        }
        assert!(j.get("aborted").is_null(), "unexpected abort: {j}");
        let seq = j.get("seq").as_u64().unwrap();
        let at = j.get("at_us").as_u64().unwrap();
        assert!(seq > last_seq, "token lines out of order: {j}");
        assert!(at >= last_at, "timestamps went backwards: {j}");
        last_seq = seq;
        last_at = at;
        tokens += 1;
    }
}

#[test]
fn mixed_classes_stream_over_loopback_with_introspection() {
    let (addr, handle) = spawn_server(paced_cfg());

    // Two concurrent connections, one per class, each consuming its own
    // ordered stream.
    let streams: Vec<_> = [("online", 64u64, 8u64), ("offline", 256, 12)]
        .into_iter()
        .map(|(class, input, output)| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut c = TcpClient::connect(&addr).unwrap();
                let out = run_one_stream(&mut c, input, output, class);
                c.call(&op("quit")).unwrap();
                out
            })
        })
        .collect();
    for s in streams {
        let (tokens, _) = s.join().unwrap();
        assert!(tokens > 0, "stream delivered no token lines");
    }

    let mut c = TcpClient::connect(&addr).unwrap();
    let health = c.call(&op("health")).unwrap();
    assert_eq!(health.get("ok").as_bool(), Some(true), "{health}");
    assert_eq!(health.get("completions").as_u64(), Some(2), "{health}");
    assert_eq!(health.get("client_aborts").as_u64(), Some(0), "{health}");
    assert_eq!(health.get("in_flight").as_u64(), Some(0), "{health}");

    let loads = c.call(&op("loads")).unwrap();
    assert_eq!(loads.get("ok").as_bool(), Some(true), "{loads}");
    assert!(loads.get("kv_token_budget").as_u64().unwrap() > 0, "{loads}");
    assert!(!loads.get("instances").as_arr().unwrap().is_empty(), "{loads}");
    assert!(!loads.get("shards").as_arr().unwrap().is_empty(), "{loads}");

    c.call(&op("shutdown")).unwrap();
    let summary = handle.join().unwrap();
    assert_eq!(summary.n_requests, 2);
    assert_eq!(summary.client_aborts, 0);
}

#[test]
fn mid_stream_kill_aborts_and_releases_all_reservations() {
    let (addr, handle) = spawn_server(paced_cfg());

    // Connection A: start a long generation, read a couple of token
    // lines to be sure it is decoding, then kill the socket.
    let mut a = TcpClient::connect(&addr).unwrap();
    let ack = a.call(&submit(64, 512, "online")).unwrap();
    assert_eq!(ack.get("ok").as_bool(), Some(true), "{ack}");
    let first = a.read_line().unwrap();
    assert!(first.get("seq").as_u64().is_some(), "{first}");
    let _ = a.read_line().unwrap();
    drop(a); // mid-stream disconnect

    // Connection B: watch `loads` until every reservation is gone. The
    // abort is only noticed when the server's next write fails, so poll
    // with a generous deadline (normally this converges in a few ms).
    let mut b = TcpClient::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let l = b.call(&op("loads")).unwrap();
        let instances = l.get("instances").as_arr().unwrap();
        let clean = l.get("kv_tokens_in_use").as_u64() == Some(0)
            && instances.iter().all(|i| {
                i.get("active").as_u64() == Some(0)
                    && i.get("pending").as_u64() == Some(0)
                    && i.get("reserved_tokens").as_u64() == Some(0)
            });
        if clean {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "abort never released reservations: {l}"
        );
        thread::sleep(Duration::from_millis(10));
    }

    let health = b.call(&op("health")).unwrap();
    assert_eq!(health.get("client_aborts").as_u64(), Some(1), "{health}");
    assert_eq!(health.get("completions").as_u64(), Some(0), "{health}");
    assert_eq!(health.get("in_flight").as_u64(), Some(0), "{health}");

    b.call(&op("shutdown")).unwrap();
    let summary = handle.join().unwrap();
    assert_eq!(summary.client_aborts, 1);
    assert_eq!(summary.n_requests, 0);
}
