#!/usr/bin/env bash
# Refresh the in-repo bench baseline snapshots (benches/baselines/).
#
# Each tracked bench prints one Summary JSON object per run row on
# stdout alongside its human-readable table; this script runs the bench
# in release mode, scrapes those lines, and rewrites the corresponding
# BENCH_<name>.json with the rows plus capture provenance. Simulation
# rows are virtual-time deterministic, so diffs in `rows` across
# commits are real scheduling changes, not hardware noise — only the
# wall-clock columns some benches print in their *tables* vary by host,
# and those are not scraped. Benches may append extra deterministic
# counters to each row as a "bench" sub-object (e.g. shard_scaling's
# plan_rounds / parallel_plans / plan_invalidations from the executor's
# plan/commit protocol); planning wall-clock stays table-only.
#
# Exception: realtime_load rows are wall-clock by nature (the realtime
# serving loop measures real sleeps and poll latency, pace-compressed),
# so its snapshot is a reference capture, not a deterministic contract —
# recapture on an idle machine and compare attainment shape, not digits.
#
# Usage: scripts/refresh_bench_baselines.sh [bench ...]
#   (default: every bench with a snapshot file in benches/baselines/)
set -euo pipefail
cd "$(dirname "$0")/.."

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
    for f in benches/baselines/BENCH_*.json; do
        b=$(basename "$f" .json)
        benches+=("${b#BENCH_}")
    done
fi

for bench in "${benches[@]}"; do
    out="benches/baselines/BENCH_${bench}.json"
    echo ">> capturing ${bench} -> ${out}"
    rows=$(cargo bench --bench "$bench" 2>/dev/null | grep '^{' | paste -sd, -)
    {
        echo '{'
        echo "  \"bench\": \"${bench}\","
        echo '  "schema": "one Summary JSON object per row, scraped from the bench'"'"'s stdout (lines starting with '"'"'{'"'"'); refresh with scripts/refresh_bench_baselines.sh",'
        echo "  \"captured_at\": \"$(date -u +%Y-%m-%dT%H:%M:%SZ)\","
        echo "  \"toolchain\": \"$(rustc --version)\","
        echo "  \"host\": \"$(uname -sm)\","
        echo "  \"rows\": [${rows}]"
        echo '}'
    } > "$out"
    n=$(grep -c '"system"' "$out" || true)
    echo "   ${n} rows"
done
