//! API stub for the `xla` crate (PJRT client over xla_extension).
//!
//! The offline build image does not ship the xla_extension native library,
//! so this crate provides just enough of the API surface for the runtime
//! layer to compile. Every entry point ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) returns an error, which surfaces to
//! users as "pjrt support not available in this build" when they try to
//! load the real engine; the simulator path never touches this crate.
//! Swap this path dependency for the real `xla` crate to enable execution.

use std::path::Path;

/// Error type mirroring the real crate's debug-printable errors.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "xla_extension not available: built against the offline stub".to_string(),
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient(());

/// Device buffer handle (stub).
pub struct PjRtBuffer(());

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable(());

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

/// Host-side literal value (stub).
pub struct Literal(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("offline stub"));
    }
}
