//! Offline stand-in for the `anyhow` crate (the build image has no
//! crates-io access). Implements the subset the workspace uses:
//! [`Error`], [`Result`], and the [`anyhow!`], [`bail!`], [`ensure!`]
//! macros, plus the blanket `From<E: std::error::Error>` conversion that
//! makes `?` work on std error types.

use std::fmt;

/// A message-carrying error. Like the real `anyhow::Error`, it
/// deliberately does **not** implement `std::error::Error`, which is what
/// keeps the blanket `From` impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        let err = parse("nope").unwrap_err();
        assert!(format!("{err}").contains("invalid digit"));
    }

    #[test]
    fn macros_format() {
        fn f(x: u32) -> crate::Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                crate::bail!("lucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{:?}", f(7).unwrap_err()), "lucky 7");
    }
}
