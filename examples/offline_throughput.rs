//! Offline throughput scenario: batch workload, SJF vs LJF vs FCFS.
//!
//! The paper's offline mode targets maximum token throughput. This example
//! runs the paper-scale simulated cluster (Llama2-13B on 4×A100) over a
//! heterogeneous Mixed batch and shows the intra-bucket policy trade-off
//! (§II-B): SJF minimizes queueing latency, LJF maximizes token
//! throughput. Pass `--engine pjrt` to run a scaled-down version on the
//! real tiny model instead.
//!
//! ```sh
//! cargo run --release --offline --example offline_throughput -- [--n 256] [--engine sim]
//! ```

use bucketserve::cluster::sim::SimEngine;
use bucketserve::cluster::Engine;
use bucketserve::config::{Policy, SystemConfig};
use bucketserve::coordinator::BucketServe;
use bucketserve::metrics::Summary;
use bucketserve::runtime::{artifacts_available, PjrtEngine, DEFAULT_ARTIFACTS_DIR};
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::util::cli::Args;
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() -> anyhow::Result<()> {
    bucketserve::util::logging::init();
    let args = Args::from_env();
    let use_pjrt = args.raw("engine") == Some("pjrt");
    let n = args.get_or("n", if use_pjrt { 24 } else { 256usize });

    let base_cfg = if use_pjrt {
        SystemConfig::tiny_pjrt()
    } else {
        SystemConfig::default()
    };
    let mut trace = Trace::batch(
        Dataset::Mixed,
        n,
        RequestClass::Offline,
        base_cfg.model.max_seq,
        base_cfg.seed,
    );
    if use_pjrt {
        for r in trace.requests.iter_mut() {
            r.output_len = r.output_len.clamp(2, 6);
        }
    }

    println!(
        "offline batch: {} mixed requests, {} total tokens ({})",
        trace.len(),
        trace.total_tokens(),
        if use_pjrt { "real PJRT engine" } else { "simulated 4×A100" }
    );

    let mut table = Table::new(&[
        "policy", "tok/s", "makespan s", "mean E2E ms", "p99 E2E ms", "util", "waste",
    ]);
    for policy in [Policy::Fcfs, Policy::Sjf, Policy::Ljf] {
        let mut cfg = base_cfg.clone();
        cfg.scheduler.policy = policy;
        let report = if use_pjrt {
            let dir = args.raw("artifacts").unwrap_or(DEFAULT_ARTIFACTS_DIR);
            if !artifacts_available(dir) {
                eprintln!("artifacts missing; run `make artifacts`");
                std::process::exit(2);
            }
            let mut engine = PjrtEngine::load(dir)?;
            engine.runtime_mut().warm_up()?;
            BucketServe::new(cfg.clone()).run(&trace, &mut engine)
        } else {
            let mut engine = SimEngine::new(&cfg);
            BucketServe::new(cfg.clone()).run(&trace, &mut engine)
        };
        let s = Summary::from_report(policy.name(), &report, &cfg.slo);
        table.row(vec![
            policy.name().to_string(),
            f1(s.throughput_tps),
            f2(s.makespan_s),
            f1(s.mean_e2e_ms),
            f1(s.p99_e2e_ms),
            f2(s.gpu_util),
            f2(s.mean_waste_ratio),
        ]);
    }
    table.print("intra-bucket policy sweep (offline, BucketServe)");
    println!("\noffline_throughput OK");
    Ok(())
}
