//! Online serving scenario: latency-SLO-bound traffic on the real engine.
//!
//! Replays a Poisson arrival trace (wall-clock pacing!) of chat-style
//! requests through BucketServe on the PJRT CPU engine, then reports SLO
//! attainment, TTFT/TBT distributions, and a comparison against the
//! DistServe-like baseline on the *same* arrivals.
//!
//! ```sh
//! cargo run --release --offline --example online_serving -- [--n 24] [--rps 4]
//! ```

use bucketserve::baselines::DistServe;
use bucketserve::config::SystemConfig;
use bucketserve::coordinator::BucketServe;
use bucketserve::metrics::Summary;
use bucketserve::runtime::{artifacts_available, PjrtEngine, DEFAULT_ARTIFACTS_DIR};
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::util::cli::Args;
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() -> anyhow::Result<()> {
    bucketserve::util::logging::init();
    let args = Args::from_env();
    let n = args.get_or("n", 24usize);
    let rps = args.get_or("rps", 4.0f64);
    let dir = args
        .raw("artifacts")
        .unwrap_or(DEFAULT_ARTIFACTS_DIR)
        .to_string();
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found in {dir}; run `make artifacts` first");
        std::process::exit(2);
    }

    let mut cfg = SystemConfig::tiny_pjrt();
    // Tight-but-achievable SLOs for the tiny CPU model.
    cfg.slo.ttft_us = 2_000_000;
    cfg.slo.tbt_us = 1_000_000;

    // Short online prompts with a few long stragglers (mixed-lite), small
    // generations so the wall-clock replay stays quick.
    let mut trace = Trace::generate(
        Dataset::Alpaca,
        n,
        rps,
        RequestClass::Online,
        cfg.model.max_seq,
        cfg.seed,
    );
    for r in trace.requests.iter_mut() {
        r.output_len = r.output_len.clamp(2, 6);
        if r.id % 7 == 3 {
            r.input_len = r.input_len.max(180); // inject long prompts
        }
    }

    println!(
        "replaying {} online requests at {} RPS (wall-clock) on the real model…",
        n, rps
    );
    let mut table = Table::new(&[
        "system", "SLO", "mean TTFT ms", "p99 TTFT ms", "mean TBT ms", "RPS",
    ]);

    for which in ["BucketServe", "DistServe"] {
        let mut engine = PjrtEngine::load(&dir)?;
        engine.runtime_mut().warm_up()?; // compile outside the timed path
        let report = match which {
            "BucketServe" => BucketServe::new(cfg.clone()).run(&trace, &mut engine),
            _ => DistServe::new(cfg.clone()).run(&trace, &mut engine),
        };
        let s = Summary::from_report(which, &report, &cfg.slo);
        table.row(vec![
            which.to_string(),
            f2(s.slo_attainment),
            f1(s.mean_ttft_ms),
            f1(s.p99_ttft_ms),
            f1(s.mean_tbt_ms),
            f2(s.server_rps),
        ]);
        println!(
            "{which}: served {}/{} requests, wall {:.1}s",
            s.n_requests,
            n,
            s.makespan_s
        );
    }
    table.print("online serving on PJRT-CPU (paired trace)");
    println!("\nonline_serving OK");
    Ok(())
}
