//! Quickstart: serve a handful of requests end-to-end on the REAL model.
//!
//! Loads the AOT-compiled JAX+Pallas artifacts (`make artifacts`), stands up
//! the PJRT CPU engine, and pushes a small batch of prompts through the
//! full BucketServe pipeline — gateway → bucketing → dynamic batching →
//! prefill → KV hand-off → continuous-batching decode — printing per-request
//! latency and generated tokens.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use bucketserve::cluster::Engine;
use bucketserve::config::SystemConfig;
use bucketserve::coordinator::BucketServe;
use bucketserve::runtime::{artifacts_available, PjrtEngine, DEFAULT_ARTIFACTS_DIR};
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Request, RequestClass, Trace};

fn main() -> anyhow::Result<()> {
    bucketserve::util::logging::init();
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| DEFAULT_ARTIFACTS_DIR.to_string());
    if !artifacts_available(&dir) {
        eprintln!("artifacts not found in {dir}; run `make artifacts` first");
        std::process::exit(2);
    }

    println!("loading AOT artifacts from {dir} …");
    let t0 = std::time::Instant::now();
    let mut engine = PjrtEngine::load(&dir)?;
    println!(
        "engine up in {:.2}s: {} params, {} compiled-shape menu",
        t0.elapsed().as_secs_f64(),
        engine.runtime().manifest.model.param_count,
        engine.runtime().manifest.artifacts.len()
    );

    // A small heterogeneous burst: short chat-like prompts plus one long
    // prompt, exactly the mix bucketing is for.
    let cfg = SystemConfig::tiny_pjrt();
    let prompts: &[(u32, u32)] = &[
        (24, 8),
        (30, 8),
        (18, 8),
        (120, 8),
        (26, 8),
        (200, 8),
        (40, 8),
        (22, 8),
    ];
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, &(inp, out))| {
            Request::new(i as u64, RequestClass::Online, inp, out, 0)
        })
        .collect();
    let trace = Trace { requests };

    println!(
        "serving {} requests through bucket → batch → P/D pipeline …",
        trace.len()
    );
    let t0 = std::time::Instant::now();
    let report = BucketServe::new(cfg.clone()).run(&trace, &mut engine);
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["req", "prompt", "gen", "TTFT ms", "E2E ms"]);
    let mut completions = report.completions.clone();
    completions.sort_by_key(|c| c.id);
    for c in &completions {
        t.row(vec![
            c.id.to_string(),
            c.input_len.to_string(),
            c.output_len.to_string(),
            f1(c.ttft() as f64 / 1e3),
            f1(c.e2e() as f64 / 1e3),
        ]);
    }
    t.print("per-request results (real PJRT execution)");

    println!(
        "\nwall time     : {:.2}s\nthroughput    : {} tok/s total, {} generated tok/s\nserver RPS    : {}\nprefill calls : {}   decode iters: {}\nGPU util proxy: {}",
        wall,
        f1(report.throughput_tps()),
        f1(report.output_tps()),
        f2(report.server_rps()),
        report.prefill_batches,
        report.decode_iters,
        f2(report.gpu_util()),
    );
    println!(
        "bucketing overhead: {:.3} ms total ({} buckets max)",
        report.bucket_overhead_ns as f64 / 1e6,
        report.max_buckets
    );
    println!("\nquickstart OK");
    Ok(())
}
