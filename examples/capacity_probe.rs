//! Capacity probe: find each system's saturation point (Fig. 5e/5f live).
//!
//! Sweeps client RPS upward on the simulated paper testbed and reports
//! server RPS + SLO attainment per system, flagging the last load each
//! system sustains at ≥80% attainment — the paper's "system load capacity"
//! comparison.
//!
//! ```sh
//! cargo run --release --offline --example capacity_probe -- [--dataset mixed] [--n 300]
//! ```

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f2, Table};
use bucketserve::util::cli::Args;
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    bucketserve::util::logging::init();
    let args = Args::from_env();
    let dataset = Dataset::parse(args.raw("dataset").unwrap_or("mixed"));
    let n = args.get_or("n", 300usize);
    let mut cfg = SystemConfig::default();
    if dataset == Dataset::Mixed {
        // Long-prompt prefill alone is ~0.7 s on this testbed: scale the
        // SLO to the workload (as DistServe-style evaluations do).
        cfg.slo.ttft_us = 1_500_000;
        cfg.slo.tbt_us = 150_000;
    }

    let loads: Vec<f64> = vec![2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0];
    let mut table = Table::new(&[
        "client RPS",
        "BS srv RPS", "BS SLO",
        "DS srv RPS", "DS SLO",
        "UE srv RPS", "UE SLO",
    ]);
    let mut capacity = [0.0f64; 3];

    for &rps in &loads {
        let trace = Trace::generate(
            dataset, n, rps, RequestClass::Online, cfg.model.max_seq, cfg.seed,
        );
        let mut row = vec![f2(rps)];
        for (i, system) in System::ALL.iter().enumerate() {
            let report = system.run_sim(&cfg, &trace);
            let slo = report.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
            // "Server RPS" at this offered load: completed over offered span.
            let srv = report.server_rps();
            if slo >= 0.8 {
                capacity[i] = capacity[i].max(rps);
            }
            row.push(f2(srv));
            row.push(f2(slo));
        }
        table.row(row);
    }
    table.print(&format!(
        "capacity probe — {} dataset, {} requests/level",
        dataset.name(),
        n
    ));

    println!("\nmax sustained load at ≥80% SLO attainment:");
    for (i, system) in System::ALL.iter().enumerate() {
        println!("  {:<12} {:>6.1} RPS", system.name(), capacity[i]);
    }
    if capacity[1] > 0.0 {
        println!(
            "  BucketServe/DistServe capacity ratio: {:.2}× (paper: 1.93× on Mixed)",
            capacity[0] / capacity[1]
        );
    }
    if capacity[2] > 0.0 {
        println!(
            "  BucketServe/UELLM capacity ratio:     {:.2}× (paper: 1.975×)",
            capacity[0] / capacity[2]
        );
    }
    println!("\ncapacity_probe OK");
}
