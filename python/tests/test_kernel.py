"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes, lengths, and tile sizes; this is the core
numerical-correctness signal for everything the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as K
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    s=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([8, 16]),
    block=st.sampled_from([8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_prefill_matches_ref(b, h, s, d, block, dtype, seed, data):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), dtype)
    k = rand(kk, (b, h, s, d), dtype)
    v = rand(kv, (b, h, s, d), dtype)
    lengths = jnp.array(
        [data.draw(st.integers(1, s)) for _ in range(b)], jnp.int32)

    out = K.prefill_attention(q, k, v, lengths, block_q=block, block_k=block)
    want = R.prefill_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_prefill_full_length_causality():
    """Output at position i must not depend on keys at positions > i."""
    b, h, s, d = 1, 2, 32, 16
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    lengths = jnp.full((b,), s, jnp.int32)

    out_full = K.prefill_attention(q, k, v, lengths)
    # Corrupt the future: change k/v beyond position 10 and check outputs at
    # positions <= 10 are unchanged.
    k2 = k.at[:, :, 11:, :].set(99.0)
    v2 = v.at[:, :, 11:, :].set(-99.0)
    out_corrupt = K.prefill_attention(q, k2, v2, lengths)
    np.testing.assert_allclose(out_full[:, :, :11], out_corrupt[:, :, :11],
                               rtol=1e-6, atol=1e-6)


def test_prefill_padding_rows_zero():
    b, h, s, d = 2, 1, 16, 8
    key = jax.random.PRNGKey(1)
    q = rand(key, (b, h, s, d), jnp.float32)
    lengths = jnp.array([5, 16], jnp.int32)
    out = K.prefill_attention(q, q, q, lengths)
    assert np.all(np.asarray(out)[0, :, 5:, :] == 0.0)
    assert not np.all(np.asarray(out)[1, :, 5:, :] == 0.0)


def test_prefill_block_sizes_equivalent():
    """Tiling must not change the math (flash recurrence invariance)."""
    b, h, s, d = 2, 2, 64, 16
    key = jax.random.PRNGKey(2)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    lengths = jnp.array([64, 40], jnp.int32)
    outs = [
        np.asarray(K.prefill_attention(q, k, v, lengths, block_q=bq, block_k=bk))
        for bq, bk in [(8, 8), (16, 32), (64, 64), (32, 8)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


def test_prefill_length_one():
    """Degenerate single-token prompt attends only to itself → out == v0."""
    b, h, s, d = 1, 1, 8, 4
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    out = K.prefill_attention(q, k, v, jnp.array([1], jnp.int32))
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    cap=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_decode_matches_ref(b, h, cap, d, dtype, seed, data):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, d), dtype)
    k = rand(kk, (b, h, cap, d), dtype)
    v = rand(kv, (b, h, cap, d), dtype)
    n_valid = jnp.array(
        [data.draw(st.integers(1, cap)) for _ in range(b)], jnp.int32)

    out = K.decode_attention(q, k, v, n_valid)
    want = R.decode_attention_ref(q, k, v, n_valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_decode_ignores_stale_cache():
    """Entries at positions >= n_valid must not affect the result."""
    b, h, cap, d = 2, 2, 32, 8
    key = jax.random.PRNGKey(5)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, d), jnp.float32)
    k = rand(kk, (b, h, cap, d), jnp.float32)
    v = rand(kv, (b, h, cap, d), jnp.float32)
    n_valid = jnp.array([7, 20], jnp.int32)
    out1 = K.decode_attention(q, k, v, n_valid)
    k2 = k.at[:, :, 25:, :].set(1e4)
    v2 = v.at[:, :, 25:, :].set(-1e4)
    out2 = K.decode_attention(q, k2, v2, n_valid)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_single_valid_entry():
    b, h, cap, d = 1, 1, 16, 4
    key = jax.random.PRNGKey(6)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, d), jnp.float32)
    k = rand(kk, (b, h, cap, d), jnp.float32)
    v = rand(kv, (b, h, cap, d), jnp.float32)
    out = K.decode_attention(q, k, v, jnp.array([1], jnp.int32))
    np.testing.assert_allclose(out[0, 0], v[0, 0, 0], rtol=1e-6, atol=1e-6)


def test_decode_consistent_with_prefill_last_row():
    """decode(q_last, cache_of_prefix) == prefill's last valid row."""
    b, h, s, d = 1, 2, 16, 8
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    length = 11
    lengths = jnp.array([length], jnp.int32)

    pre = K.prefill_attention(q, k, v, lengths)               # (B,H,S,D)
    dec = K.decode_attention(q[:, :, length - 1, :], k, v,
                             jnp.array([length], jnp.int32))
    np.testing.assert_allclose(dec, pre[:, :, length - 1, :],
                               rtol=1e-5, atol=1e-5)


def test_vmem_footprint_reasonable():
    """Structural perf check: default tiles fit comfortably in 16 MiB VMEM."""
    bytes_ = K.vmem_footprint_bytes(K.DEFAULT_BLOCK_Q, K.DEFAULT_BLOCK_K, 128)
    assert bytes_ < 16 * 1024 * 1024 / 4   # << quarter of VMEM
