"""L2 correctness: model shapes, prefill/decode consistency, AOT contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

# A deliberately tiny config so interpret-mode pallas stays fast in CI.
CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
                    ffn_dim=64, kv_capacity=24, max_prefill=16)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def test_param_shapes_order_stable():
    names = M.param_names(CFG)
    assert names[0] == "embed"
    assert names[-1] == "lm_head"
    assert len(names) == 2 + 9 * CFG.n_layers + 1
    # Canonical order must be deterministic — the Rust loader depends on it.
    assert names == M.param_names(CFG)


def test_init_params_deterministic():
    a = M.init_params(CFG, seed=7)
    b = M.init_params(CFG, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_prefill_shapes(params):
    b, s = 2, 8
    tokens = jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) % CFG.vocab
    lengths = jnp.array([8, 5], jnp.int32)
    logits, kc, vc = M.prefill(params, tokens, lengths, CFG)
    assert logits.shape == (b, CFG.vocab)
    assert kc.shape == (CFG.n_layers, b, CFG.n_heads, CFG.kv_capacity,
                        CFG.head_dim)
    assert vc.shape == kc.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_prefill_padding_invariant(params):
    """Tokens beyond a sequence's length must not change its logits."""
    b, s = 1, 8
    tokens = jnp.ones((b, s), jnp.int32) * 3
    lengths = jnp.array([5], jnp.int32)
    logits1, _, _ = M.prefill(params, tokens, lengths, CFG)
    tokens2 = tokens.at[0, 5:].set(61)
    logits2, _, _ = M.prefill(params, tokens2, lengths, CFG)
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                               rtol=1e-5, atol=1e-5)


def test_decode_step_shapes(params):
    b = 2
    kv = jnp.zeros((CFG.n_layers, b, CFG.n_heads, CFG.kv_capacity,
                    CFG.head_dim), jnp.float32)
    tokens = jnp.array([1, 2], jnp.int32)
    pos = jnp.array([0, 3], jnp.int32)
    logits, kc, vc = M.decode_step(params, tokens, kv, kv, pos, CFG)
    assert logits.shape == (b, CFG.vocab)
    assert kc.shape == kv.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_decode_writes_kv_at_pos(params):
    b = 1
    kv = jnp.zeros((CFG.n_layers, b, CFG.n_heads, CFG.kv_capacity,
                    CFG.head_dim), jnp.float32)
    pos = jnp.array([4], jnp.int32)
    _, kc, _ = M.decode_step(params, jnp.array([9], jnp.int32), kv, kv, pos,
                             CFG)
    kc = np.asarray(kc)
    assert np.any(kc[:, 0, :, 4, :] != 0.0)          # written at pos
    assert np.all(np.delete(kc, 4, axis=3) == 0.0)   # everywhere else intact


def test_prefill_then_decode_matches_longer_prefill(params):
    """decode_step(prefill(t[:n])) ≈ prefill(t[:n+1]) — phase hand-off."""
    s = 8
    tokens = (jnp.arange(s, dtype=jnp.int32) * 7 + 3) % CFG.vocab
    n = 5

    # Path A: prefill the first n tokens, then decode token n.
    logits_a, kc, vc = M.prefill(params, tokens[None, :],
                                 jnp.array([n], jnp.int32), CFG)
    logits_b, _, _ = M.decode_step(params, tokens[None, n], kc, vc,
                                   jnp.array([n], jnp.int32), CFG)

    # Path B: prefill the first n+1 tokens directly.
    logits_full, _, _ = M.prefill(params, tokens[None, :],
                                  jnp.array([n + 1], jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               rtol=1e-4, atol=1e-4)


def test_greedy_generation_deterministic(params):
    """End-to-end greedy loop is reproducible (the rust runtime mirrors it)."""
    s = 4
    tokens = jnp.array([[1, 2, 3, 4]], jnp.int32)
    lengths = jnp.array([s], jnp.int32)

    def run():
        logits, kc, vc = M.prefill(params, tokens, lengths, CFG)
        out = []
        pos = s
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(4):
            out.append(int(tok[0]))
            logits, kc, vc = M.decode_step(params, tok, kc, vc,
                                           jnp.array([pos], jnp.int32), CFG)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos += 1
        return out

    assert run() == run()


def test_param_count_matches_shapes():
    total = sum(int(np.prod(s)) for _, s in M.param_shapes(CFG))
    assert CFG.param_count() == total
