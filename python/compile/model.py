"""Layer-2 JAX model: a LLaMA-style decoder-only transformer.

Build-time only — this module is lowered by ``aot.py`` to HLO text and then
executed from the Rust runtime; Python never sits on the request path.

Architecture (matching the families the paper serves, scaled tiny for the
CPU test bed): token embedding → N × [RMSNorm → RoPE multi-head attention →
residual → RMSNorm → SwiGLU MLP → residual] → RMSNorm → LM head.

Two entry points mirror the disaggregated phases:

* ``prefill(params, tokens[B,S], lengths[B])``
    → ``(last_logits[B,V], k_cache[L,B,H,CAP,D], v_cache[L,B,H,CAP,D])``
  Runs the whole (bucket-padded) prompt through the stack, returns the
  next-token logits at each sequence's true last position plus the KV cache
  padded to the decode capacity CAP, ready for NVLink-style hand-off.

* ``decode_step(params, tokens[B], k_cache, v_cache, pos[B])``
    → ``(logits[B,V], k_cache', v_cache')``
  One continuous-batching iteration: appends each sequence's K/V at its own
  position and attends over its own valid prefix.

Attention in both phases calls the Layer-1 Pallas kernels
(``kernels.attention``), so the kernels lower into the same HLO artifact.

Parameters travel as a flat tuple (deterministic jax pytree flattening
order); ``param_names``/``init_params`` define that order and ``aot.py``
records it in the artifact manifest for the Rust loader.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import attention as kernels


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of the served model (tiny default for CPU e2e)."""
    vocab: int = 1024
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    ffn_dim: int = 384
    kv_capacity: int = 320      # decode-phase KV cache capacity (max ctx)
    max_prefill: int = 256      # largest prefill bucket bound
    rope_base: float = 10000.0

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(self))


def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) for every weight tensor, in the canonical flat order."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.qkv_dim)),
            (p + "wk", (cfg.d_model, cfg.qkv_dim)),
            (p + "wv", (cfg.d_model, cfg.qkv_dim)),
            (p + "wo", (cfg.qkv_dim, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.ffn_dim)),
            (p + "w_up", (cfg.d_model, cfg.ffn_dim)),
            (p + "w_down", (cfg.ffn_dim, cfg.d_model)),
        ]
    shapes += [
        ("final_norm", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return shapes


def param_names(cfg: ModelConfig) -> List[str]:
    return [n for n, _ in param_shapes(cfg)]


def init_params(cfg: ModelConfig, seed: int = 42):
    """Deterministic random weights (the 'small real model' for e2e runs)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 1.0 / (fan_in ** 0.5)
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * scale)
    return tuple(params)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, base: float):
    """Rotary embedding. x: (..., T, H, D) or (..., H, D); positions matches T."""
    d = x.shape[-1]
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _unpack(params, cfg: ModelConfig):
    names = param_names(cfg)
    return dict(zip(names, params))


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, tokens, lengths, cfg: ModelConfig):
    """Full-prompt forward pass; see module docstring for the contract."""
    p = _unpack(params, cfg)
    b, s = tokens.shape
    h, d = cfg.n_heads, cfg.head_dim
    cap = cfg.kv_capacity

    x = p["embed"][tokens]                                     # (B, S, M)
    positions = jnp.arange(s)[None, :].repeat(b, axis=0)       # (B, S)

    k_cache = []
    v_cache = []
    for i in range(cfg.n_layers):
        lp = f"layer{i}."
        xa = rmsnorm(x, p[lp + "attn_norm"])
        q = (xa @ p[lp + "wq"]).reshape(b, s, h, d)
        k = (xa @ p[lp + "wk"]).reshape(b, s, h, d)
        v = (xa @ p[lp + "wv"]).reshape(b, s, h, d)
        q = rope(q, positions, cfg.rope_base)
        k = rope(k, positions, cfg.rope_base)

        # Kernels want (B, H, S, D).
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        attn = kernels.prefill_attention(qt, kt, vt, lengths)  # (B,H,S,D)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, h * d)
        x = x + attn @ p[lp + "wo"]

        xm = rmsnorm(x, p[lp + "mlp_norm"])
        x = x + swiglu(xm, p[lp + "w_gate"], p[lp + "w_up"], p[lp + "w_down"])

        # Pad K/V to decode capacity for the phase hand-off.
        pad = [(0, 0), (0, 0), (0, cap - s), (0, 0)]
        k_cache.append(jnp.pad(kt, pad))
        v_cache.append(jnp.pad(vt, pad))

    x = rmsnorm(x, p["final_norm"])
    # Next-token logits at each sequence's true last position.
    last_idx = jnp.clip(lengths - 1, 0, s - 1)                 # (B,)
    last_h = jnp.take_along_axis(
        x, last_idx[:, None, None].repeat(cfg.d_model, axis=2), axis=1
    )[:, 0, :]                                                 # (B, M)
    logits = last_h @ p["lm_head"]                             # (B, V)

    return logits, jnp.stack(k_cache), jnp.stack(v_cache)      # (L,B,H,CAP,D)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def decode_step(params, tokens, k_cache, v_cache, pos, cfg: ModelConfig):
    """One continuous-batching decode iteration; see module docstring."""
    p = _unpack(params, cfg)
    b = tokens.shape[0]
    h, d = cfg.n_heads, cfg.head_dim
    cap = cfg.kv_capacity

    x = p["embed"][tokens]                                     # (B, M)
    # One-hot scatter index for per-sequence insertion position.
    onehot = jax.nn.one_hot(pos, cap, dtype=jnp.float32)       # (B, CAP)

    new_k = []
    new_v = []
    for i in range(cfg.n_layers):
        lp = f"layer{i}."
        xa = rmsnorm(x, p[lp + "attn_norm"])
        q = (xa @ p[lp + "wq"]).reshape(b, h, d)
        k = (xa @ p[lp + "wk"]).reshape(b, h, d)
        v = (xa @ p[lp + "wv"]).reshape(b, h, d)
        q = rope(q, pos, cfg.rope_base)                        # (B, H, D)
        k = rope(k, pos, cfg.rope_base)

        # Insert this step's K/V at each sequence's own position.
        ins = onehot[:, None, :, None]                         # (B,1,CAP,1)
        kc = k_cache[i] * (1.0 - ins) + k[:, :, None, :] * ins
        vc = v_cache[i] * (1.0 - ins) + v[:, :, None, :] * ins
        new_k.append(kc)
        new_v.append(vc)

        attn = kernels.decode_attention(q, kc, vc, pos + 1)    # (B, H, D)
        x = x + attn.reshape(b, h * d) @ p[lp + "wo"]

        xm = rmsnorm(x, p[lp + "mlp_norm"])
        x = x + swiglu(xm, p[lp + "w_gate"], p[lp + "w_up"], p[lp + "w_down"])

    x = rmsnorm(x, p["final_norm"])
    logits = x @ p["lm_head"]                                  # (B, V)
    return logits, jnp.stack(new_k), jnp.stack(new_v)
