"""AOT compiler: lower the L2 model (with L1 Pallas kernels) to HLO text.

Run once at build time (``make artifacts``); Python is never on the request
path.  Emits into ``artifacts/``:

* ``prefill_b{B}_s{S}.hlo.txt`` — one prefill executable per
  (batch-size, bucket-upper-bound) pair.  Bucket bounds ARE the compiled
  static shapes: BucketServe's pad-to-bucket-bound batching contract maps
  1:1 onto the AOT executable cache (DESIGN.md §3).
* ``decode_b{B}.hlo.txt`` — one continuous-batching decode step per batch
  size, with a fixed KV capacity.
* ``weights.bin`` — deterministic (seeded) f32 weights, flat little-endian,
  in the canonical ``model.param_shapes`` order.
* ``manifest.json`` — model config + weight table (name/shape/offset) +
  artifact table (file/kind/batch/seq/input-output contract), consumed by
  ``rust/src/runtime/artifacts.rs``.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

# Batch sizes and prefill bucket bounds compiled ahead of time.  These are
# the shape menu the Rust coordinator's BucketManager selects from.
PREFILL_BATCHES = (1, 2, 4, 8)
PREFILL_BUCKETS = (32, 64, 128, 256)
DECODE_BATCHES = (1, 2, 4, 8)
SEED = 42


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: M.ModelConfig, batch: int, seq: int) -> str:
    fn = functools.partial(M.prefill, cfg=cfg)
    params_spec = tuple(
        jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_shapes(cfg))
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lengths = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(params_spec, tokens, lengths))


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    fn = functools.partial(M.decode_step, cfg=cfg)
    params_spec = tuple(
        jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_shapes(cfg))
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.n_heads, cfg.kv_capacity, cfg.head_dim),
        jnp.float32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(params_spec, tokens, kv, kv, pos))


def write_weights(cfg: M.ModelConfig, out_dir: str):
    params = M.init_params(cfg, seed=SEED)
    table = []
    offset = 0
    blob = bytearray()
    for (name, shape), arr in zip(M.param_shapes(cfg), params):
        import numpy as np
        data = np.asarray(arr, dtype="<f4").tobytes()
        table.append({
            "name": name,
            "shape": list(shape),
            "offset": offset,
            "bytes": len(data),
        })
        blob.extend(data)
        offset += len(data)
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        f.write(bytes(blob))
    return table, offset, hashlib.sha256(bytes(blob)).hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--small", action="store_true",
                    help="compile a reduced shape menu (fast CI mode)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.ModelConfig()
    prefill_batches = (1, 4) if args.small else PREFILL_BATCHES
    prefill_buckets = (32, 128) if args.small else PREFILL_BUCKETS
    decode_batches = (1, 4) if args.small else DECODE_BATCHES

    weight_table, total_bytes, sha = write_weights(cfg, args.out_dir)
    print(f"weights.bin: {total_bytes} bytes "
          f"({sum(1 for _ in weight_table)} tensors) sha256={sha[:16]}…")

    pnames = M.param_names(cfg)
    artifacts = []
    for b in prefill_batches:
        for s in prefill_buckets:
            name = f"prefill_b{b}_s{s}"
            text = lower_prefill(cfg, b, s)
            with open(os.path.join(args.out_dir, name + ".hlo.txt"), "w") as f:
                f.write(text)
            artifacts.append({
                "name": name, "kind": "prefill", "batch": b, "seq": s,
                "file": name + ".hlo.txt",
                "inputs": pnames + ["tokens", "lengths"],
                "outputs": ["last_logits", "k_cache", "v_cache"],
            })
            print(f"{name}: {len(text)} chars")
    for b in decode_batches:
        name = f"decode_b{b}"
        text = lower_decode(cfg, b)
        with open(os.path.join(args.out_dir, name + ".hlo.txt"), "w") as f:
            f.write(text)
        artifacts.append({
            "name": name, "kind": "decode", "batch": b,
            "seq": cfg.kv_capacity, "file": name + ".hlo.txt",
            "inputs": pnames + ["tokens", "k_cache", "v_cache", "pos"],
            "outputs": ["logits", "k_cache", "v_cache"],
        })
        print(f"{name}: {len(text)} chars")

    manifest = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim, "ffn_dim": cfg.ffn_dim,
            "kv_capacity": cfg.kv_capacity, "max_prefill": cfg.max_prefill,
            "param_count": int(cfg.param_count()),
        },
        "seed": SEED,
        "weights": {"file": "weights.bin", "total_bytes": total_bytes,
                    "sha256": sha, "tensors": weight_table},
        "artifacts": artifacts,
        "interchange": "hlo-text",
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json: {len(artifacts)} artifacts, "
          f"{manifest['model']['param_count']} params")


if __name__ == "__main__":
    main()
