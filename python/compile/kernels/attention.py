"""Layer-1 Pallas attention kernels for BucketServe.

Two kernels cover the two phases of disaggregated serving:

* ``prefill_attention`` — tiled, flash-style causal attention over a whole
  (padded-to-bucket-bound) prompt.  The grid iterates over
  (batch, head, query-block); each program streams K/V blocks through VMEM
  with the running log-sum-exp recurrence, so the S×S score matrix is never
  materialized.  This is the TPU re-think of FlashAttention's threadblock
  SRAM tiling (DESIGN.md §8): BlockSpec expresses the HBM→VMEM schedule, and
  the inner ``jnp.dot`` contractions are MXU-shaped.

* ``decode_attention`` — one query token per sequence against a fixed-capacity
  KV cache, masked by the current position.  This is the bandwidth-bound
  kernel (the whole KV cache streams through once per generated token).

Both kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path is the correctness (and AOT
lowering) vehicle; real-TPU efficiency is estimated structurally in
DESIGN.md §7/§8.

Correctness oracle: ``ref.py`` (pure jnp), exercised by hypothesis sweeps in
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  On a real TPU these would be MXU-aligned (128 lanes /
# 8 sublanes); the tiny e2e model uses shorter sequences, so tiles clamp to
# the actual extent.  Kept as module constants so tests can sweep them.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() NaN-free


def _prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                    seq_len: int, scale: float):
    """One (batch, head, q-block) program of flash-style causal attention.

    Refs (per BlockSpec; leading singleton block dims retained by pallas):
      len_ref : (1,)                int32 — valid length of this sequence
      q_ref   : (1, 1, block_q, d)  queries for this block
      k_ref   : (1, 1, seq, d)      full K for this (batch, head)
      v_ref   : (1, 1, seq, d)      full V
      o_ref   : (1, 1, block_q, d)  output block
    """
    block_q = q_ref.shape[2]
    d = q_ref.shape[3]
    q_blk = pl.program_id(2)
    q_off = q_blk * block_q

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (block_q, d)
    k_full = k_ref[0, 0]                                   # (seq, d)
    v_full = v_ref[0, 0]
    length = len_ref[0]

    # Flash recurrence state: running max m, normalizer l, accumulator acc.
    m = jnp.full((block_q,), _NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q,), dtype=jnp.float32)
    acc = jnp.zeros((block_q, d), dtype=jnp.float32)

    num_kb = pl.cdiv(seq_len, block_k)
    q_pos = q_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(kb, carry):
        m, l, acc = carry
        k_off = kb * block_k
        k_tile = jax.lax.dynamic_slice_in_dim(k_full, k_off, block_k, axis=0)
        v_tile = jax.lax.dynamic_slice_in_dim(v_full, k_off, block_k, axis=0)
        s = jnp.dot(q, k_tile.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)  # (block_q, block_k)

        k_pos = k_off + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (k_pos <= q_pos) & (k_pos < length)
        s = jnp.where(mask, s, _NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        # Rows that are fully masked keep p≈0 because s==_NEG_INF==m_new only
        # when the row never saw a real score; guard the degenerate exp(0)=1.
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v_tile.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m, l, acc))

    # Padded query rows (q_pos >= length) have l == 0; emit zeros for them.
    safe_l = jnp.where(l > 0.0, l, 1.0)
    out = acc / safe_l[:, None]
    out = jnp.where((q_off + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, d), 0)) < length, out, 0.0)
    o_ref[0, 0] = out.astype(o_ref.dtype)


def prefill_attention(q, k, v, lengths, *, block_q: int = DEFAULT_BLOCK_Q,
                      block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """Causal, length-masked multi-head attention for the prefill phase.

    Args:
      q, k, v: (B, H, S, D) arrays (any float dtype; accumulates in f32).
      lengths: (B,) int32 valid lengths; positions >= length are padding and
        produce zero outputs (they never contribute as keys either).
      block_q/block_k: VMEM tile extents (clamped to S).
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      (B, H, S, D) attention outputs, same dtype as q.
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    scale = 1.0 / (d ** 0.5)
    grid = (b, h, pl.cdiv(s, block_q))

    kernel = functools.partial(_prefill_kernel, block_k=block_k, seq_len=s,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, iq: (ib,)),
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, s, d), lambda ib, ih, iq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda ib, ih, iq: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch, head) program: single query vs. the whole KV cache.

    Refs (leading singleton block dims retained by pallas):
      pos_ref : (1,)            int32 — number of valid cache entries (attend
                                to positions [0, pos); the current token's K/V
                                must already be at index pos-1)
      q_ref   : (1, 1, d)       the query
      k_ref   : (1, 1, cap, d)  KV-cache keys
      v_ref   : (1, 1, cap, d)  KV-cache values
      o_ref   : (1, 1, d)
    """
    cap = k_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale            # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (cap, d)
    v = v_ref[0, 0].astype(jnp.float32)
    n_valid = pos_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, cap)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)
    s = jnp.where(idx < n_valid, s, _NEG_INF)

    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(idx < n_valid, p, 0.0)
    l = jnp.sum(p, axis=1, keepdims=True)
    out = jnp.dot(p, v, preferred_element_type=jnp.float32) / jnp.maximum(l, 1e-30)
    o_ref[0] = out.astype(o_ref.dtype)


def decode_attention(q, k, v, n_valid, *, interpret: bool = True):
    """Single-token attention for the decode phase.

    Args:
      q: (B, H, D) current-step queries.
      k, v: (B, H, CAP, D) KV cache (CAP = bucket-capacity padding).
      n_valid: (B,) int32 — entries [0, n_valid) of the cache are live,
        *including* the current token's K/V at n_valid - 1.

    Returns:
      (B, H, D) attention outputs.
    """
    b, h, d = q.shape
    cap = k.shape[2]
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_decode_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih: (ib,)),
            pl.BlockSpec((1, 1, d), lambda ib, ih: (ib, ih, 0)),
            pl.BlockSpec((1, 1, cap, d), lambda ib, ih: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, cap, d), lambda ib, ih: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda ib, ih: (ib, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(n_valid, q, k, v)
    return out


def vmem_footprint_bytes(block_q: int, block_k: int, d: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one prefill program (DESIGN.md §7).

    q tile + k tile + v tile + output tile + flash state (m, l, acc in f32).
    """
    tiles = (block_q * d + 2 * block_k * d + block_q * d) * dtype_bytes
    state = (2 * block_q + block_q * d) * 4
    return tiles + state
