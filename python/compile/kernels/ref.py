"""Pure-jnp reference oracles for the Pallas kernels.

These are deliberately naive (materialize the full score matrix) so they are
easy to audit; the pytest/hypothesis suite asserts the Pallas kernels match
them to numerical tolerance across shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

_NEG_INF = -1e30


def prefill_attention_ref(q, k, v, lengths):
    """Naive causal + length-masked attention. Shapes as kernels.prefill."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    causal = k_pos <= q_pos                                       # (s, s)
    valid_k = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    mask = causal[None, None, :, :] & valid_k                     # (b,1,s,s)
    scores = jnp.where(mask, scores, _NEG_INF)

    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-30), vf)

    valid_q = jnp.arange(s)[None, None, :, None] < lengths[:, None, None, None]
    out = jnp.where(valid_q, out, 0.0)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, n_valid):
    """Naive single-token attention. Shapes as kernels.decode_attention."""
    b, h, d = q.shape
    cap = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    scores = jnp.einsum("bhd,bhkd->bhk", qf, kf)
    idx = jnp.arange(cap)[None, None, :]
    mask = idx < n_valid[:, None, None]
    scores = jnp.where(mask, scores, _NEG_INF)

    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)                                       # (b, h)
    out = jnp.einsum("bhk,bhkd->bhd", p, vf) / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
